"""Vitis-HLS C++ emission from the schedule IR (the paper's emithls stage).

MING's final stage translates its ``emithls`` dialect to Vitis HLS C++.
We reproduce that artifact: :func:`emit_design` consumes a
:class:`~repro.core.compile_driver.CompiledDesign` and emits one
complete DATAFLOW kernel per :class:`GroupSchedule` plus the host-side
group schedule, with the five pragma families the paper highlights
(Sec. III-C):

  STREAM, UNROLL, PIPELINE (II=1), ARRAY_PARTITION, BIND_STORAGE,
  plus the top-level DATAFLOW region.

Weight-streamed nodes (``DseResult.weight_tiles``) emit the
double-buffered ``wtile[2][…]`` ping/pong array, a ``WT`` tile loop
with prefetch, and ``m_axi`` DRAM weight pointers; windowed (pooling)
epilogues emit their partial-row buffer; the host schedule overlaps
each group's spill write with the next group's fill through an async
DMA queue (matching ``transition_cycles``).  ``emit_cpp`` remains the
per-plan workhorse underneath.

The emitter is golden-file tested; it cannot be synthesized in this
container (no Vitis), but it is the faithful end of the reproduction
pipeline and demonstrates that the schedule IR carries every datum the
HLS backend needs.
"""
from __future__ import annotations

import math
from typing import Iterable

from .analysis import KernelClass, window_geometry
from .dse import DseResult
from .ir import PayloadKind
from .streaming import NodePlan, StreamingPlan

_CTYPE = {8: "ap_int<8>", 16: "ap_int<16>", 32: "ap_int<32>"}

_PAYLOAD_EXPR = {
    PayloadKind.MAC: "acc += (accum_t)win[i] * (accum_t)wgt[i];",
    PayloadKind.ADD: "out_v = a_v + b_v;",
    PayloadKind.MAX: "out_v = (a_v > b_v) ? a_v : b_v;",
    PayloadKind.AVG: "acc += (accum_t)win[i];  // avg-pool accumulate",
    PayloadKind.RELU: "out_v = (in_v > 0) ? in_v : (elem_t)0;",
    PayloadKind.SQUARED_RELU: "out_v = (in_v > 0) ? (elem_t)(in_v * in_v) : (elem_t)0;",
    PayloadKind.IDENTITY: "out_v = in_v;",
    PayloadKind.MUL: "out_v = a_v * b_v;",
    PayloadKind.EXP: "out_v = hls::exp(in_v);",
}

#: fused-epilogue templates: {v} is the node's result variable (``acc``
#: for MAC nodes, ``out_v`` otherwise), {k} the on-chip constant operand.
_EPILOGUE_EXPR = {
    PayloadKind.RELU: "{v} = ({v} > 0) ? {v} : 0;",
    PayloadKind.SQUARED_RELU: "{v} = ({v} > 0) ? {v} * {v} : 0;",
    PayloadKind.IDENTITY: "",
    PayloadKind.EXP: "{v} = hls::exp({v});",
    PayloadKind.ADD: "{v} += {k};",
    PayloadKind.MUL: "{v} *= {k};",
    PayloadKind.MAX: "{v} = ({v} > {k}) ? {v} : {k};",
}


def _floor_div_stmt(var: str, pts: int) -> str:
    """The DIV exit path as *floor* division — C's `/` truncates toward
    zero, which would diverge from ``ref.pool_reduce`` by 1 LSB on
    negative sums.  Power-of-two windows (the common 2×2/4×4 pools) are
    an arithmetic right shift, which floors exactly; other factors get
    the explicit remainder adjustment."""
    if pts & (pts - 1) == 0:
        return f"{var} >>= {pts.bit_length() - 1};"
    return f"{var} = ({var} - ((({var} % {pts}) + {pts}) % {pts})) / {pts};"


def _emit_epilogue(op, indent: str, values: dict | None = None) -> list[str]:
    """Fused-epilogue lines applied to the result before stream write.

    ``values`` (when provided) sizes constant operands: an operand with
    fewer elements than the output is a broadcast (per-channel bias) and
    indexes modulo its own length — full-size operands keep the plain
    ``[o]`` subscript."""
    var = "acc" if op.payload in (PayloadKind.MAC, PayloadKind.AVG) else "out_v"
    lines = []
    if op.payload == PayloadKind.AVG:
        # standalone avg pool: the divide rides the stream-exit datapath
        # once per output point, after the window accumulation completes
        pts = math.prod(op.dim_sizes[d] for d in op.reduction_dims)
        lines.append(
            f"{indent}{_floor_div_stmt(var, pts)}  "
            f"// avg-pool DIV exit path (/{pts}, floor)"
        )
    for e in op.epilogue:
        if e.window:
            # windowed (pooling) entry: the row buffer holds partial
            # reductions until the window's leading axis fills
            f = "x".join(str(x) for x in e.window if x > 1)
            if e.kind == PayloadKind.MAX:
                lines.append(
                    f"{indent}pool_line[o % POOL_LINE] = "
                    f"({var} > pool_line[o % POOL_LINE]) ? {var} : "
                    f"pool_line[o % POOL_LINE];  // fused {e.kind.value}-pool /{f}"
                )
            else:  # ADD / AVG: accumulate into the partial row
                lines.append(
                    f"{indent}pool_line[o % POOL_LINE] += {var};  "
                    f"// fused {e.kind.value}-pool /{f}"
                )
                if e.kind == PayloadKind.AVG:
                    # divide exactly once per pooled output — on the
                    # window's last row, when the slot has received all
                    # prod(window) contributions (dividing every step
                    # would divide partial sums repeatedly)
                    pts = math.prod(e.window)
                    lead = next(x for x in e.window if x > 1)
                    div = _floor_div_stmt(f"pool_line[o % POOL_LINE]", pts)
                    lines.append(
                        f"{indent}if ((o / POOL_LINE) % {lead} == {lead - 1}) "
                        f"{div}  "
                        f"// avg-pool DIV exit path (/{pts}, floor, window full)"
                    )
            continue
        # `o` is the flat output-point index, same schematic convention
        # as the payload's `win[i]`/`wgt[i]` accesses
        k = ""
        if e.operand:
            idx = "o"
            if values is not None:
                n = values[e.operand].num_elements
                if n < values[op.output].num_elements:
                    idx = f"o % {n}"  # broadcast (per-channel) operand
            k = f"k_{e.operand}[{idx}]"
        expr = _EPILOGUE_EXPR[e.kind].format(v=var, k=k)
        if expr:
            lines.append(f"{indent}{expr}  // fused {e.kind.value}")
    return lines


def _pool_line_elems(op, values) -> int:
    """Partial-row buffer length for the first fused pooling epilogue."""
    for e in op.epilogue:
        if e.window and any(f > 1 for f in e.window):
            shape = values[op.output].shape
            first = next(i for i, f in enumerate(e.window) if f > 1)
            n = 1
            for a in range(first + 1, len(shape)):
                n *= shape[a]
            return max(n, 1)
    return 0


def _ctype(bits: int) -> str:
    return _CTYPE.get(bits, f"ap_int<{bits}>")


def dram_weight_values(plan: StreamingPlan, dse: DseResult) -> list[str]:
    """Constant values whose node streams them from DRAM (weight_tiles>1):
    these become m_axi pointer ports instead of on-chip ROMs."""
    out: list[str] = []
    for np_ in plan.node_order():
        if dse.weight_tiles.get(np_.name, 1) > 1:
            for i in np_.op.inputs:
                if plan.dfg.values[i].is_constant and i not in out:
                    out.append(i)
    return out


def emit_node(plan: NodePlan, unroll: int, width: int,
              values: dict | None = None, weight_tiles: int = 1) -> str:
    """One dataflow process function for a node.

    ``weight_tiles > 1`` emits the partial-weight-streaming realization:
    a double-buffered (ping/pong) tile array fed from DRAM and a tile
    loop wrapping the nest, with the tiled output-channel trip divided.
    """
    op = plan.op
    lines: list[str] = []
    ins = ", ".join(
        f"hls::stream<elem_t> &{s}" for s in plan.input_streams
    )
    outs = ", ".join(
        f"hls::stream<elem_t> &{s}" for s in plan.output_streams
    )
    args = ", ".join(x for x in (ins, outs) if x)
    if weight_tiles > 1:
        wnames = [i for i in op.inputs if values and values[i].is_constant]
        wargs = ", ".join(f"const elem_t *dram_{v}" for v in wnames)
        args = ", ".join(x for x in (args, wargs) if x)
    lines.append(f"void {op.name}({args}) {{")

    # fused-epilogue constants (bias/scale) live on-chip next to the
    # weights, one element per output point (identity-map fusion)
    for e in op.epilogue:
        if e.operand:
            n = values[e.operand].num_elements if values else 1
            lines.append(f"  static elem_t k_{e.operand}[{n}];  // fused-const")

    # fused-pool partial row (windowed epilogue)
    pool_elems = _pool_line_elems(op, values) if values else 0
    if pool_elems:
        lines.append(f"  #define POOL_LINE {pool_elems}")
        lines.append(f"  static elem_t pool_line[{pool_elems}];  // fused-pool row")
        lines.append(
            "#pragma HLS BIND_STORAGE variable=pool_line type=ram_2p impl=bram"
        )

    if weight_tiles > 1:
        tile_elems = max(
            plan.const_buffer_bits // max(op.elem_bits, 1) // weight_tiles, 1
        )
        lines.append(
            f"  elem_t wtile[2][{tile_elems}];  "
            f"// double-buffered DRAM weight tile (1/{weight_tiles} of the set)"
        )
        lines.append("#pragma HLS ARRAY_PARTITION variable=wtile dim=1 complete")
        lines.append(
            "#pragma HLS BIND_STORAGE variable=wtile type=ram_2p impl=bram"
        )

    if plan.kernel_class == KernelClass.SLIDING_WINDOW:
        geo = window_geometry(op, plan.info)
        if len(geo.window_dims) >= 2:
            k_outer = geo.window_extents[0]
            line_len = geo.input_extents[-1]
            stride_note = ""
            if op.payload == PayloadKind.MAC and geo.stride > 1:
                # strided conv: the line shifter still holds K-1 input
                # rows, but only every stride-th window row is emitted
                stride_note = (
                    f"  // stride {geo.stride}: ingest {geo.stride} input "
                    "rows per output row"
                )
            lines.append(
                f"  elem_t line_buf[{max(k_outer - 1, 1)}][{line_len}];"
                f"{stride_note}"
            )
            lines.append(
                "#pragma HLS BIND_STORAGE variable=line_buf type=ram_2p impl=bram"
            )
            lines.append(
                f"#pragma HLS ARRAY_PARTITION variable=line_buf dim=1 complete"
            )
        win = math.prod(geo.window_extents)
        lines.append(f"  elem_t win[{win}];")
        lines.append("#pragma HLS ARRAY_PARTITION variable=win complete")
        lines.append(f"  elem_t wgt[{win}];")
        lines.append("#pragma HLS ARRAY_PARTITION variable=wgt complete")
    elif plan.kernel_class == KernelClass.REGULAR_REDUCTION:
        red = max(plan.line_buffer_bits // max(op.elem_bits, 1), 1)
        lines.append(f"  elem_t line[{red}];")
        part = min(unroll, red)
        lines.append(
            f"#pragma HLS ARRAY_PARTITION variable=line cyclic factor={part}"
        )

    # loop nest.  The epilogue applies once per *output point*: for MAC
    # nodes that is after the trailing window/reduction loops complete
    # (the accumulator is final there); pure-parallel nodes produce one
    # output per innermost iteration, so it stays next to the payload.
    inner_acc = 0
    if plan.kernel_class != KernelClass.PURE_PARALLEL:
        # trailing loops of the nest (plan_node puts reductions innermost)
        inner_acc = len(plan.info.classes.reduction)

    trips = list(plan.loops.trip_counts)
    depth = 0
    if weight_tiles > 1:
        # tile loop wraps the nest; the tiled output-channel dim runs
        # 1/weight_tiles of its extent per pass
        if plan.weight_tile_dims and plan.loop_dims:
            tpos = plan.loop_dims.index(plan.weight_tile_dims[0])
            trips[tpos] = max(trips[tpos] // weight_tiles, 1)
        wname = next(
            (i for i in op.inputs if values and values[i].is_constant), "w"
        )
        lines.append(f"  load_tile(wtile[0], dram_{wname}, 0);  // preload tile 0")
        lines.append(
            f"  WT: for (int wt = 0; wt < {weight_tiles}; ++wt) {{"
        )
        lines.append(
            f"    if (wt + 1 < {weight_tiles}) "
            f"load_tile(wtile[(wt + 1) & 1], dram_{wname}, wt + 1);  "
            "// prefetch next tile while computing from wtile[wt & 1]"
        )
        depth = 1
    for i, trip in enumerate(trips):
        indent = "  " * (depth + 1)
        lines.append(f"{indent}L{i}: for (int i{i} = 0; i{i} < {trip}; ++i{i}) {{")
        depth += 1
        if i == len(trips) - 1:
            indent = "  " * (depth + 1)
            lines.append(f"{indent}#pragma HLS PIPELINE II=1")
            if unroll > 1:
                lines.append(f"{indent}#pragma HLS UNROLL factor={unroll}")
            body = _PAYLOAD_EXPR[op.payload]
            lines.append(f"{indent}{body}")
            if inner_acc == 0:
                lines.extend(_emit_epilogue(op, indent, values))
    inner_acc = min(inner_acc, max(depth - 1, 0))
    has_exit = bool(op.epilogue) or op.payload == PayloadKind.AVG
    for j, i in enumerate(range(depth, 0, -1)):
        lines.append("  " * i + "}")
        if has_exit and inner_acc and j + 1 == inner_acc:
            # just closed the accumulation loops: acc is final here
            lines.extend(_emit_epilogue(op, "  " * i, values))
    lines.append("}")
    return "\n".join(lines)


def emit_cpp(
    plan: StreamingPlan,
    dse: DseResult,
    top_name: str | None = None,
    *,
    m_axi_wrapper: bool = False,
) -> str:
    """Emit the full Vitis-style C++ translation unit.

    ``m_axi_wrapper=True`` additionally emits an ``extern "C"``
    ``<top>_m_axi(elem_t *...)`` entry whose pointer arguments are the
    graph's input/output *values* (DDR buffers) — the symbol the
    host-side layer-group schedule links against.
    """
    top = top_name or plan.dfg.name
    parts: list[str] = [
        "// Generated by MING-repro emithls backend",
        "#include <hls_stream.h>",
        "#include <ap_int.h>",
        "",
        f"typedef {_ctype(8)} elem_t;",
        f"typedef {_ctype(32)} accum_t;",
        "",
    ]
    order = plan.node_order()
    for np_ in order:
        u = dse.unrolls.get(np_.name, 1)
        w = dse.stream_widths.get(np_.name, 1)
        t = dse.weight_tiles.get(np_.name, 1)
        parts.append(emit_node(np_, u, w, values=plan.dfg.values,
                               weight_tiles=t))
        parts.append("")

    # top-level DATAFLOW region
    gi = [s for s in plan.streams.values() if s.producer is None]
    go = [s for s in plan.streams.values() if s.consumer is None]
    dram_w = dram_weight_values(plan, dse)
    args = ", ".join(
        [f"hls::stream<elem_t> &{s.name}" for s in gi]
        + [f"hls::stream<elem_t> &{s.name}" for s in go]
        + [f"const elem_t *dram_{v}" for v in dram_w]
    )
    parts.append(f"void {top}({args}) {{")
    parts.append("#pragma HLS DATAFLOW")
    for s in plan.streams.values():
        if s.producer is not None and s.consumer is not None:
            parts.append(f"  hls::stream<elem_t> {s.name};")
            parts.append(
                f"#pragma HLS STREAM variable={s.name} depth={s.depth}"
            )
    for np_ in order:
        call_args = list(np_.input_streams + np_.output_streams)
        if dse.weight_tiles.get(np_.name, 1) > 1:
            call_args += [
                f"dram_{v}" for v in np_.op.inputs
                if plan.dfg.values[v].is_constant
            ]
        parts.append(f"  {np_.op.name}({', '.join(call_args)});")
    parts.append("}")
    parts.append("")

    if m_axi_wrapper:
        io_values = list(plan.dfg.graph_inputs) + list(plan.dfg.graph_outputs)
        wargs = ", ".join(
            [f"elem_t *{v}" for v in io_values]
            + [f"const elem_t *{v}" for v in dram_w]
        )
        parts.append(f'extern "C" void {top}_m_axi({wargs}) {{')
        for v in io_values + dram_w:
            parts.append(f"#pragma HLS INTERFACE m_axi port={v} offset=slave")
        for s in gi + go:
            parts.append(f"  hls::stream<elem_t> {s.name};")
        parts.append("  // DMA: DDR -> input streams, run, output streams -> DDR")
        parts.append(
            f"  {top}("
            + ", ".join([s.name for s in gi + go] + [v for v in dram_w])
            + ");"
        )
        parts.append("}")
        parts.append("")
    return "\n".join(parts)


# ---------------------------------------------------------------------------
# Whole-design emission off the schedule IR (repro.core.compile_driver)
# ---------------------------------------------------------------------------


def emit_design(design) -> dict[str, str]:
    """Emit a :class:`repro.core.compile_driver.CompiledDesign`: one
    translation unit per group schedule plus the host-side schedule that
    runs them sequentially (single-group designs get one kernel and a
    trivial host schedule).

    Returns ``{filename: contents}``: ``<group>.cpp`` per group (each a
    complete DATAFLOW kernel, top function named after the group) and
    ``host_schedule.cpp`` declaring the DRAM spill buffers (and any
    streamed-weight buffers) and invoking the group kernels in order.
    Every datum comes from the design's :class:`GroupSchedule`s — no
    plan state is re-derived here.
    """
    import repro.instrument as instrument

    tracer = instrument.current()
    files: dict[str, str] = {}
    with tracer.span(f"emit:{design.source.name}", cat="emit") as eargs:
        for g in design.groups:
            with tracer.span(f"emit:{g.name}.cpp", cat="emit") as gargs:
                files[f"{g.name}.cpp"] = emit_cpp(
                    g.plan, g.dse, top_name=g.name, m_axi_wrapper=True
                )
                gargs.update({"bytes": len(files[f"{g.name}.cpp"]),
                              "nodes": len(g.dfg.nodes)})
        with tracer.span("emit:host_schedule.cpp", cat="emit") as hargs:
            files["host_schedule.cpp"] = emit_host_schedule(design)
            hargs["bytes"] = len(files["host_schedule.cpp"])
        eargs["files"] = len(files)
    return files


#: historical name (PR 1 API): the partitioned and monolithic paths are
#: now the same single entry point over the schedule IR
emit_partitioned = emit_design


def emit_host_schedule(pp) -> str:
    """The host-side group schedule (the artifact a partitioned design
    adds over a monolithic one).

    Group transitions issue *overlapped* DMA: the spill write of group
    *k* is queued asynchronously and the fill of group *k+1* streams one
    burst behind it (``dma_write_async`` / ``dma_read_async`` /
    ``dma_join``), matching the
    :func:`repro.core.resource_model.transition_cycles` cost model —
    ``max(spill, fill)`` plus the exposed burst tail, not a serial
    round trip.
    """
    from .resource_model import transition_cycles

    src = pp.source
    lines = [
        "// Generated by MING-repro emithls backend — layer-group schedule",
        f"// source graph: {src.name} | groups: {len(pp.groups)} | "
        f"peak BRAM {pp.max_bram}/{pp.b_total} | peak DSP {pp.max_dsp}/{pp.d_total}",
        "#include <cstddef>",
        "",
        "typedef signed char elem_t;",
        "",
    ]
    if pp.partitioned:
        lines += [
            "// async DMA queue: spill writes of group k overlap the fill of",
            "// group k+1 (the read trails the write by one DRAM burst)",
            "void dma_write_async(const elem_t *buf, size_t bytes);",
            "void dma_read_async(elem_t *buf, size_t bytes);",
            "void dma_join();  // barrier: all queued transfers retired",
            "",
        ]
    group_weights = {g.name: dram_weight_values(g.plan, g.dse) for g in pp.groups}
    for g in pp.groups:
        args = ["elem_t *" + v for v in g.dfg.graph_inputs]
        args += ["elem_t *" + v for v in g.dfg.graph_outputs]
        args += ["const elem_t *" + v for v in group_weights[g.name]]
        lines.append(
            f'extern "C" void {g.name}_m_axi({", ".join(args)});  // kernel'
        )
    lines.append("")
    for s in pp.spills():
        lines.append(
            f"static elem_t spill_{s.value}[{s.bytes}];  "
            f"// DRAM boundary buffer ({s.bytes / 1024:.1f} KiB)"
        )
    for g in pp.groups:
        for v in group_weights[g.name]:
            b = math.ceil(src.values[v].total_bits / 8)
            lines.append(
                f"static elem_t wstream_{v}[{b}];  "
                f"// DRAM-resident streamed weights ({b / 1024:.1f} KiB)"
            )
    lines.append("")
    io = ["elem_t *" + v for v in src.graph_inputs] + [
        "elem_t *" + v for v in src.graph_outputs
    ]
    lines.append(f"void run_{src.name}({', '.join(io)}) {{")
    lines.append(
        "  // groups execute sequentially; one bitstream resident at a time"
    )
    spilled = {s.value for s in pp.spills()}

    def ref(v: str) -> str:
        return f"spill_{v}" if v in spilled else v

    traffic = pp.boundary_traffic()
    for gi, g in enumerate(pp.groups):
        call = [ref(v) for v in g.dfg.graph_inputs + g.dfg.graph_outputs]
        call += [f"wstream_{v}" for v in group_weights[g.name]]
        streamed = g.weight_streamed
        note = (
            f", weights streamed {streamed}" if streamed else ""
        )
        lines.append(
            f"  // {g.name}: {', '.join(n.name for n in g.dfg.nodes)} "
            f"(BRAM {g.bram}, DSP {g.dsp}, {g.cycles} cycles{note})"
        )
        lines.append(f"  {g.name}_m_axi({', '.join(call)});")
        if gi < len(pp.groups) - 1:
            nxt = pp.groups[gi + 1]
            wb, rb = traffic[gi]
            cyc = transition_cycles(wb, rb)
            lines.append(
                f"  // transition {g.name} -> {nxt.name}: write {wb} B "
                f"overlaps read {rb} B — {cyc} cycles modeled"
            )
            for v in g.spill_out:
                b = math.ceil(src.values[v].total_bits / 8)
                lines.append(f"  dma_write_async({ref(v)}, {b});")
            for v in nxt.spill_in:
                # a spill_in that is also a graph output was written to
                # its host-visible buffer (a run_* parameter), not to a
                # spill_* staging buffer — read whichever buffer the
                # next kernel call actually receives
                b = math.ceil(src.values[v].total_bits / 8)
                lines.append(f"  dma_read_async({ref(v)}, {b});")
            lines.append("  dma_join();")
    lines.append("}")
    lines.append("")
    return "\n".join(lines)
