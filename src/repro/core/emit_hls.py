"""Vitis-HLS C++ emission from a StreamingPlan (the paper's emithls stage).

MING's final stage translates its ``emithls`` dialect to Vitis HLS C++.
We reproduce that artifact: given a :class:`StreamingPlan` and a
:class:`~repro.core.dse.DseResult`, emit a compilable-style C++ file with
the five pragma families the paper highlights (Sec. III-C):

  STREAM, UNROLL, PIPELINE (II=1), ARRAY_PARTITION, BIND_STORAGE,
  plus the top-level DATAFLOW region.

The emitter is golden-file tested; it cannot be synthesized in this
container (no Vitis), but it is the faithful end of the reproduction
pipeline and demonstrates that the plan carries every datum the HLS
backend needs.
"""
from __future__ import annotations

import math
from typing import Iterable

from .analysis import KernelClass, window_geometry
from .dse import DseResult
from .ir import PayloadKind
from .streaming import NodePlan, StreamingPlan

_CTYPE = {8: "ap_int<8>", 16: "ap_int<16>", 32: "ap_int<32>"}

_PAYLOAD_EXPR = {
    PayloadKind.MAC: "acc += (accum_t)win[i] * (accum_t)wgt[i];",
    PayloadKind.ADD: "out_v = a_v + b_v;",
    PayloadKind.MAX: "out_v = (a_v > b_v) ? a_v : b_v;",
    PayloadKind.RELU: "out_v = (in_v > 0) ? in_v : (elem_t)0;",
    PayloadKind.SQUARED_RELU: "out_v = (in_v > 0) ? (elem_t)(in_v * in_v) : (elem_t)0;",
    PayloadKind.IDENTITY: "out_v = in_v;",
    PayloadKind.MUL: "out_v = a_v * b_v;",
    PayloadKind.EXP: "out_v = hls::exp(in_v);",
}

#: fused-epilogue templates: {v} is the node's result variable (``acc``
#: for MAC nodes, ``out_v`` otherwise), {k} the on-chip constant operand.
_EPILOGUE_EXPR = {
    PayloadKind.RELU: "{v} = ({v} > 0) ? {v} : 0;",
    PayloadKind.SQUARED_RELU: "{v} = ({v} > 0) ? {v} * {v} : 0;",
    PayloadKind.IDENTITY: "",
    PayloadKind.EXP: "{v} = hls::exp({v});",
    PayloadKind.ADD: "{v} += {k};",
    PayloadKind.MUL: "{v} *= {k};",
    PayloadKind.MAX: "{v} = ({v} > {k}) ? {v} : {k};",
}


def _emit_epilogue(op, indent: str) -> list[str]:
    """Fused-epilogue lines applied to the result before stream write."""
    var = "acc" if op.payload == PayloadKind.MAC else "out_v"
    lines = []
    for e in op.epilogue:
        # `o` is the flat output-point index, same schematic convention
        # as the payload's `win[i]`/`wgt[i]` accesses
        k = f"k_{e.operand}[o]" if e.operand else ""
        expr = _EPILOGUE_EXPR[e.kind].format(v=var, k=k)
        if expr:
            lines.append(f"{indent}{expr}  // fused {e.kind.value}")
    return lines


def _ctype(bits: int) -> str:
    return _CTYPE.get(bits, f"ap_int<{bits}>")


def emit_node(plan: NodePlan, unroll: int, width: int,
              values: dict | None = None) -> str:
    """One dataflow process function for a node."""
    op = plan.op
    lines: list[str] = []
    ins = ", ".join(
        f"hls::stream<elem_t> &{s}" for s in plan.input_streams
    )
    outs = ", ".join(
        f"hls::stream<elem_t> &{s}" for s in plan.output_streams
    )
    args = ", ".join(x for x in (ins, outs) if x)
    lines.append(f"void {op.name}({args}) {{")

    # fused-epilogue constants (bias/scale) live on-chip next to the
    # weights, one element per output point (identity-map fusion)
    for e in op.epilogue:
        if e.operand:
            n = values[e.operand].num_elements if values else 1
            lines.append(f"  static elem_t k_{e.operand}[{n}];  // fused-const")

    if plan.kernel_class == KernelClass.SLIDING_WINDOW:
        geo = window_geometry(op, plan.info)
        if len(geo.window_dims) >= 2:
            k_outer = geo.window_extents[0]
            line_len = geo.input_extents[-1]
            lines.append(
                f"  elem_t line_buf[{max(k_outer - 1, 1)}][{line_len}];"
            )
            lines.append(
                "#pragma HLS BIND_STORAGE variable=line_buf type=ram_2p impl=bram"
            )
            lines.append(
                f"#pragma HLS ARRAY_PARTITION variable=line_buf dim=1 complete"
            )
        win = math.prod(geo.window_extents)
        lines.append(f"  elem_t win[{win}];")
        lines.append("#pragma HLS ARRAY_PARTITION variable=win complete")
        lines.append(f"  elem_t wgt[{win}];")
        lines.append("#pragma HLS ARRAY_PARTITION variable=wgt complete")
    elif plan.kernel_class == KernelClass.REGULAR_REDUCTION:
        red = max(plan.line_buffer_bits // max(op.elem_bits, 1), 1)
        lines.append(f"  elem_t line[{red}];")
        part = min(unroll, red)
        lines.append(
            f"#pragma HLS ARRAY_PARTITION variable=line cyclic factor={part}"
        )

    # loop nest.  The epilogue applies once per *output point*: for MAC
    # nodes that is after the trailing window/reduction loops complete
    # (the accumulator is final there); pure-parallel nodes produce one
    # output per innermost iteration, so it stays next to the payload.
    inner_acc = 0
    if plan.kernel_class != KernelClass.PURE_PARALLEL:
        # trailing loops of the nest (plan_node puts reductions innermost)
        inner_acc = len(plan.info.classes.reduction)
    depth = 0
    for i, trip in enumerate(plan.loops.trip_counts):
        indent = "  " * (depth + 1)
        lines.append(f"{indent}L{i}: for (int i{i} = 0; i{i} < {trip}; ++i{i}) {{")
        depth += 1
        if i == len(plan.loops.trip_counts) - 1:
            indent = "  " * (depth + 1)
            lines.append(f"{indent}#pragma HLS PIPELINE II=1")
            if unroll > 1:
                lines.append(f"{indent}#pragma HLS UNROLL factor={unroll}")
            body = _PAYLOAD_EXPR[op.payload]
            lines.append(f"{indent}{body}")
            if inner_acc == 0:
                lines.extend(_emit_epilogue(op, indent))
    inner_acc = min(inner_acc, max(depth - 1, 0))
    for j, i in enumerate(range(depth, 0, -1)):
        lines.append("  " * i + "}")
        if op.epilogue and inner_acc and j + 1 == inner_acc:
            # just closed the accumulation loops: acc is final here
            lines.extend(_emit_epilogue(op, "  " * i))
    lines.append("}")
    return "\n".join(lines)


def emit_cpp(
    plan: StreamingPlan,
    dse: DseResult,
    top_name: str | None = None,
    *,
    m_axi_wrapper: bool = False,
) -> str:
    """Emit the full Vitis-style C++ translation unit.

    ``m_axi_wrapper=True`` additionally emits an ``extern "C"``
    ``<top>_m_axi(elem_t *...)`` entry whose pointer arguments are the
    graph's input/output *values* (DDR buffers) — the symbol the
    host-side layer-group schedule links against.
    """
    top = top_name or plan.dfg.name
    parts: list[str] = [
        "// Generated by MING-repro emithls backend",
        "#include <hls_stream.h>",
        "#include <ap_int.h>",
        "",
        f"typedef {_ctype(8)} elem_t;",
        f"typedef {_ctype(32)} accum_t;",
        "",
    ]
    order = plan.node_order()
    for np_ in order:
        u = dse.unrolls.get(np_.name, 1)
        w = dse.stream_widths.get(np_.name, 1)
        parts.append(emit_node(np_, u, w, values=plan.dfg.values))
        parts.append("")

    # top-level DATAFLOW region
    gi = [s for s in plan.streams.values() if s.producer is None]
    go = [s for s in plan.streams.values() if s.consumer is None]
    args = ", ".join(
        [f"hls::stream<elem_t> &{s.name}" for s in gi]
        + [f"hls::stream<elem_t> &{s.name}" for s in go]
    )
    parts.append(f"void {top}({args}) {{")
    parts.append("#pragma HLS DATAFLOW")
    for s in plan.streams.values():
        if s.producer is not None and s.consumer is not None:
            parts.append(f"  hls::stream<elem_t> {s.name};")
            parts.append(
                f"#pragma HLS STREAM variable={s.name} depth={s.depth}"
            )
    for np_ in order:
        call_args = ", ".join(np_.input_streams + np_.output_streams)
        parts.append(f"  {np_.op.name}({call_args});")
    parts.append("}")
    parts.append("")

    if m_axi_wrapper:
        io_values = list(plan.dfg.graph_inputs) + list(plan.dfg.graph_outputs)
        wargs = ", ".join(f"elem_t *{v}" for v in io_values)
        parts.append(f'extern "C" void {top}_m_axi({wargs}) {{')
        for v in io_values:
            parts.append(f"#pragma HLS INTERFACE m_axi port={v} offset=slave")
        for s in gi + go:
            parts.append(f"  hls::stream<elem_t> {s.name};")
        parts.append("  // DMA: DDR -> input streams, run, output streams -> DDR")
        parts.append(
            f"  {top}(" + ", ".join(s.name for s in gi + go) + ");"
        )
        parts.append("}")
        parts.append("")
    return "\n".join(parts)


# ---------------------------------------------------------------------------
# Multi-group emission (layer-group partitioning, repro.passes.partition)
# ---------------------------------------------------------------------------


def emit_partitioned(pp) -> dict[str, str]:
    """Emit a partitioned design: one translation unit per layer group
    plus the host-side schedule that runs them sequentially.

    ``pp`` is a :class:`repro.passes.partition.PartitionPlan`.  Returns
    ``{filename: contents}``: ``<group>.cpp`` per group (each a complete
    DATAFLOW kernel, top function named after the group) and
    ``host_schedule.cpp`` declaring the DRAM spill buffers and invoking
    the group kernels in order.
    """
    files: dict[str, str] = {}
    for g in pp.groups:
        files[f"{g.name}.cpp"] = emit_cpp(
            g.plan, g.dse, top_name=g.name, m_axi_wrapper=True
        )
    files["host_schedule.cpp"] = emit_host_schedule(pp)
    return files


def emit_host_schedule(pp) -> str:
    """The host-side group schedule (the artifact a partitioned design
    adds over a monolithic one)."""
    src = pp.source
    lines = [
        "// Generated by MING-repro emithls backend — layer-group schedule",
        f"// source graph: {src.name} | groups: {len(pp.groups)} | "
        f"peak BRAM {pp.max_bram}/{pp.b_total} | peak DSP {pp.max_dsp}/{pp.d_total}",
        "#include <cstddef>",
        "",
        "typedef signed char elem_t;",
        "",
    ]
    for g in pp.groups:
        args = ["elem_t *" + v for v in g.dfg.graph_inputs]
        args += ["elem_t *" + v for v in g.dfg.graph_outputs]
        lines.append(
            f'extern "C" void {g.name}_m_axi({", ".join(args)});  // kernel'
        )
    lines.append("")
    for s in pp.spills():
        lines.append(
            f"static elem_t spill_{s.value}[{s.bytes}];  "
            f"// DRAM boundary buffer ({s.bytes / 1024:.1f} KiB)"
        )
    lines.append("")
    io = ["elem_t *" + v for v in src.graph_inputs] + [
        "elem_t *" + v for v in src.graph_outputs
    ]
    lines.append(f"void run_{src.name}({', '.join(io)}) {{")
    lines.append(
        "  // groups execute sequentially; one bitstream resident at a time"
    )
    spilled = {s.value for s in pp.spills()}

    def ref(v: str) -> str:
        return f"spill_{v}" if v in spilled else v

    for g in pp.groups:
        row = (
            f"  {g.name}_m_axi("
            + ", ".join(ref(v) for v in g.dfg.graph_inputs + g.dfg.graph_outputs)
            + ");"
        )
        lines.append(
            f"  // {g.name}: {', '.join(n.name for n in g.dfg.nodes)} "
            f"(BRAM {g.bram}, DSP {g.dsp}, {g.cycles} cycles)"
        )
        lines.append(row)
    lines.append("}")
    lines.append("")
    return "\n".join(lines)
