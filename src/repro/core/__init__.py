"""MING core: the paper's contribution as a composable library.

Pipeline (paper Fig. 4):
  DFG of GenericOps → analysis (Alg. 1+2) → streaming transform (streams +
  line buffers) → ILP DSE (Eq. 1) → backends (Vitis-style C++ emission /
  Pallas block planning).
"""
from .analysis import (
    IteratorClasses,
    KernelClass,
    KernelInfo,
    SlidingWindowInfo,
    classify_iterators,
    classify_kernel,
    detect_sliding_window,
    window_geometry,
)
from .dse import (
    DseResult,
    divisors,
    plan_attention_blocks,
    plan_conv_rows,
    plan_matmul_blocks,
    solve_ilp,
    solve_materialized,
)
from .ir import (
    DFG,
    AffineExpr,
    AffineMap,
    GenericOp,
    IteratorType,
    PayloadKind,
    Value,
    make_conv2d_op,
    make_elementwise_op,
    make_matmul_op,
    make_pool2d_op,
)
from .resource_model import (
    ExecMode,
    FpgaResourceModel,
    GraphEstimate,
    KV260_BRAM18K,
    KV260_DSP,
    TPU_V5E,
    TpuResourceModel,
    TpuSpec,
)
from .streaming import FusionRegion, NodePlan, StreamEdge, StreamingPlan, plan_streams

__all__ = [k for k in dir() if not k.startswith("_")]
