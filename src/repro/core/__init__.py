"""MING core: the paper's contribution as a composable library.

Pipeline (paper Fig. 4):
  DFG of GenericOps → analysis (Alg. 1+2) → streaming transform (streams +
  line buffers) → ILP DSE (Eq. 1) → backends (Vitis-style C++ emission /
  Pallas block planning).
"""
from .analysis import (
    IteratorClasses,
    KernelClass,
    KernelInfo,
    SlidingWindowInfo,
    classify_iterators,
    classify_kernel,
    detect_sliding_window,
    window_geometry,
)
from .compile_driver import (
    KV260,
    TARGETS,
    ZU3EG,
    CompiledDesign,
    CompileOptions,
    GroupSchedule,
    Target,
    compile_design,
)
from .dse import (
    DseResult,
    divisors,
    plan_attention_blocks,
    plan_conv_rows,
    plan_matmul_blocks,
    solve_ilp,
    solve_materialized,
)
from .ir import (
    DFG,
    AffineExpr,
    AffineMap,
    FusedEpilogue,
    GenericOp,
    IteratorType,
    PayloadKind,
    Value,
    make_conv2d_op,
    make_elementwise_op,
    make_matmul_op,
    make_pool2d_op,
)
from .resource_model import (
    ExecMode,
    FpgaResourceModel,
    GraphEstimate,
    KV260_BRAM18K,
    KV260_DSP,
    TPU_V5E,
    TpuResourceModel,
    TpuSpec,
)
from .streaming import FusionRegion, NodePlan, StreamEdge, StreamingPlan, plan_streams

#: pass-pipeline API re-exported lazily (PEP 562) — ``repro.passes``
#: imports ``repro.core`` submodules, so an eager import here would cycle.
_PASSES_EXPORTS = (
    "Pass",
    "PassManager",
    "PassStats",
    "PipelineResult",
    "Canonicalize",
    "CommonSubexprElimination",
    "DeadCodeElimination",
    "ElementwiseChainFusion",
    "ConvActivationFusion",
    "ConvPoolFusion",
    "LayerGroup",
    "PartitionError",
    "PartitionPlan",
    "SpillBuffer",
    "partition_layer_groups",
    "VerificationError",
    "verify_dfg",
    "default_pipeline",
    "run_default_pipeline",
)


def __getattr__(name: str):
    if name in _PASSES_EXPORTS:
        from repro import passes as _passes

        return getattr(_passes, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [k for k in dir() if not k.startswith("_")] + list(_PASSES_EXPORTS)
