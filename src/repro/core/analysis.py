"""MING kernel analysis (paper Sec. IV-A).

Faithful re-implementations of the paper's two structural analyses over
``linalg.generic``-like ops:

* **Algorithm 1 — sliding-window detection.**  A kernel is sliding-window
  iff some input indexing-map result can be written ``E = s*i_p + δ*i_r``
  with ``i_p`` parallel and ``i_r`` reduction; the coefficients are the
  stride ``s`` and dilation ``δ``.

* **Algorithm 2 — iterator classification** into the four sets that drive
  stream / line-buffer construction (Sec. IV-B):
  𝒫 parallel dims (output-stream shape), ℛ reduction dims (input-stream
  shape), 𝒪 original input dims (line-buffer axes), 𝒲 window dims
  (compute-window extent).

Both run in ``O(Σ|E|)`` over the inspected affine maps, matching the
paper's complexity claim.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass

from .ir import AffineExpr, GenericOp, IteratorType


class KernelClass(str, enum.Enum):
    PURE_PARALLEL = "pure_parallel"
    REGULAR_REDUCTION = "regular_reduction"
    SLIDING_WINDOW = "sliding_window"


@dataclass(frozen=True)
class SlidingWindowInfo:
    is_sliding_window: bool
    stride: int
    dilation: int


def detect_sliding_window(op: GenericOp) -> SlidingWindowInfo:
    """Paper Algorithm 1.

    Walk every result expression of every *input* indexing map; try to
    rewrite it as ``A + B`` where each term is ``iterator * const``.  If one
    iterator is parallel and the other reduction, the op slides: the
    parallel coefficient is the stride, the reduction coefficient the
    dilation.
    """
    # line 1: if all iterators are parallel -> (false, 0, 0)
    if all(t == IteratorType.PARALLEL for t in op.iterator_types):
        return SlidingWindowInfo(False, 0, 0)
    # lines 2-11: scan input maps
    for m in op.input_maps:
        for expr in m.results:
            # try to rewrite E as A + B with A=(i_a * c_a), B=(i_b * c_b)
            if len(expr.terms) != 2 or expr.const != 0:
                continue
            (i_a, c_a), (i_b, c_b) = expr.terms
            a_par = op.is_parallel_dim(i_a)
            b_par = op.is_parallel_dim(i_b)
            # line 6: one parallel, the other reduction
            if a_par != b_par:
                if a_par:
                    stride, dilation = c_a, c_b
                else:
                    stride, dilation = c_b, c_a
                if stride > 0 and dilation > 0:
                    return SlidingWindowInfo(True, stride, dilation)
    return SlidingWindowInfo(False, 0, 0)


@dataclass(frozen=True)
class IteratorClasses:
    """The four sets of paper Algorithm 2 (dims are loop-dim indices)."""

    parallel: tuple[int, ...]        # 𝒫 — define output-stream shape
    reduction: tuple[int, ...]       # ℛ — define input-stream shape
    original_input: tuple[AffineExpr, ...]  # 𝒪 — composite exprs -> line buffer
    window: tuple[int, ...]          # 𝒲 — compute-window extent


def classify_iterators(op: GenericOp) -> IteratorClasses:
    """Paper Algorithm 2 (verbatim structure).

    Input-map results that are single dims go to 𝒫 (parallel) or ℛ
    (reduction); composite results go to 𝒪.  Output-map results that are
    parallel but *not* already in 𝒫 are the window dims 𝒲.
    """
    P: list[int] = []
    R: list[int] = []
    O: list[AffineExpr] = []
    W: list[int] = []
    for m in op.input_maps:                       # line 2
        for expr in m.results:                    # line 3
            if expr.is_single_dim():              # line 4 IS_SINGLE_DIM
                (d, _), = expr.terms
                if op.is_parallel_dim(d):         # line 5
                    if d not in P:
                        P.append(d)
                else:                             # line 6
                    if d not in R:
                        R.append(d)
            else:                                 # line 8-9
                if expr not in O:
                    O.append(expr)
    for expr in op.output_map.results:            # line 13
        if expr.is_single_dim():
            (d, _), = expr.terms
            if op.is_parallel_dim(d) and d not in P:   # line 14
                W.append(d)
    return IteratorClasses(tuple(P), tuple(R), tuple(O), tuple(W))


@dataclass(frozen=True)
class KernelInfo:
    """Joint result of Alg. 1 + Alg. 2 plus the final classification
    (Sec. IV-A: pure parallel / regular reduction / sliding window)."""

    kernel_class: KernelClass
    stride: int
    dilation: int
    classes: IteratorClasses

    @property
    def window_extents_known(self) -> bool:
        return self.kernel_class == KernelClass.SLIDING_WINDOW


def einsum_spec(op: GenericOp) -> str:
    """``jnp.einsum`` subscript string for a regular reduction whose map
    results are all single dims (matmul and friends) — shared by the DFG
    interpreter and the per-group Pallas lowering so both execute the
    same contraction the maps describe."""
    letters = "abcdefghijklmnopqrstuvwxyz"
    subs = []
    for m in op.indexing_maps:
        if not all(e.is_single_dim() for e in m.results):
            raise NotImplementedError(f"{op.name}: composite map in einsum path")
        subs.append("".join(letters[e.terms[0][0]] for e in m.results))
    return ",".join(subs[:-1]) + "->" + subs[-1]


def reorder_spec(
    op: GenericOp,
) -> tuple[str, tuple[int, ...]] | None:
    """Recognize pure data-movement ops from their maps alone.

    Returns ``("transpose", perm)`` for an axis permutation
    (:func:`repro.core.ir.make_transpose_op`), ``("flatten", order)``
    for a mixed-radix linearization
    (:func:`repro.core.ir.make_flatten_op` — ``order`` is the
    linearization order of the non-batch axes), or ``None`` for
    anything else.  Shared by the interpreter, the Pallas lowering, and
    the layout pass so all three agree on what a reorder op *is*
    without a payload flag.
    """
    from .ir import PayloadKind  # local: avoid widening module surface

    if (
        op.payload != PayloadKind.IDENTITY
        or len(op.inputs) != 1
        or any(t != IteratorType.PARALLEL for t in op.iterator_types)
    ):
        return None
    imap, omap = op.indexing_maps
    n = op.n_dims
    # transpose: identity output map, permuted single-dim input map
    if omap.is_identity() and all(e.is_single_dim() for e in imap.results):
        dims = tuple(e.terms[0][0] for e in imap.results)
        if len(imap.results) == n and sorted(dims) == list(range(n)):
            if imap.is_identity():
                return None  # plain wire, canonicalize's business
            # input axis k is mapped by loop dim dims[k]; the output
            # axis order is the inverse permutation
            perm = [0] * n
            for k, d in enumerate(dims):
                perm[d] = k
            return ("transpose", tuple(perm))
    # flatten: identity input map, (d0, Σ stride_ax·d_ax) output map
    if (
        imap.is_identity()
        and len(omap.results) == 2
        and omap.results[0] == AffineExpr.dim(0)
        and omap.results[1].const == 0
    ):
        terms = dict(omap.results[1].terms)
        if set(terms) != set(range(1, n)) or any(c < 1 for c in terms.values()):
            return None
        # recover the linearization order greedily from the innermost
        # stride outwards.  Extent-1 axes tie on stride with their
        # neighbour (they don't advance it), so they must be consumed
        # first at each stride level — any order among equal-stride
        # extent-1 axes yields the identical output map.
        remaining = dict(terms)
        rev: list[int] = []
        stride = 1
        while remaining:
            cands = [ax for ax, c in remaining.items() if c == stride]
            ones = sorted(ax for ax in cands if op.dim_extent(ax) == 1)
            if ones:
                ax = ones[0]
            elif len(cands) == 1:
                ax = cands[0]
            else:
                return None  # not a mixed-radix linearization
            rev.append(ax)
            del remaining[ax]
            stride *= op.dim_extent(ax)
        return ("flatten", tuple(reversed(rev)))
    return None


def classify_kernel(op: GenericOp) -> KernelInfo:
    sw = detect_sliding_window(op)
    classes = classify_iterators(op)
    if sw.is_sliding_window:
        kc = KernelClass.SLIDING_WINDOW
    elif any(t == IteratorType.REDUCTION for t in op.iterator_types):
        kc = KernelClass.REGULAR_REDUCTION
    else:
        kc = KernelClass.PURE_PARALLEL
    return KernelInfo(kc, sw.stride, sw.dilation, classes)


# ---------------------------------------------------------------------------
# Derived geometry used by the streaming transform (Sec. IV-B)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class WindowGeometry:
    """Geometry of a sliding-window kernel extracted from the maps.

    For a 2-D conv with input N×N and kernel K×K the paper's line buffer
    is ``(K-1) × N`` plus a ``K × K`` window buffer; this struct is the
    n-dimensional generalization the transform consumes.
    """

    window_dims: tuple[int, ...]          # 𝒲 (spatial output dims)
    window_extents: tuple[int, ...]       # trip counts of reduction dims
    #  paired with each window dim
    input_extents: tuple[int, ...]        # full extents of the 𝒪 exprs
    stride: int
    dilation: int


def conv_spatial_pads(
    op: GenericOp, input_shape: tuple[int, ...]
) -> tuple[tuple[int, int], ...]:
    """Explicit ``(begin, end)`` zero-padding per physical input axis.

    The affine maps fully determine how much input a sliding-window op
    *reads*: along a windowed axis the accesses span
    ``s*(P-1) + δ*(R-1) + 1`` elements.  Whatever that exceeds the
    producer's actual extent must be zero-padding, split end-heavy
    (``begin = total // 2``) — the XLA SAME / ONNX SAME_UPPER
    convention, and for odd kernels at stride 1 exactly the symmetric
    ``(k-1)//2`` frame the original stride-1 path used.  A VALID window
    (maps read no more than the input provides) yields ``(0, 0)``
    everywhere, so the same helper serves both conventions; pool ops
    (always VALID here) get all-zero pads too.
    """
    info = classify_kernel(op)
    if info.kernel_class != KernelClass.SLIDING_WINDOW:
        raise ValueError(f"{op.name} is not sliding-window")
    imap = op.input_maps[0]
    pads: list[tuple[int, int]] = []
    for ax, expr in enumerate(imap.results):
        par = red = None
        if not expr.is_single_dim() and expr.const == 0:
            for d, c in expr.terms:
                if op.is_parallel_dim(d):
                    par = (d, c)
                else:
                    red = (d, c)
        if par is None or red is None:
            pads.append((0, 0))
            continue
        needed = (
            par[1] * (op.dim_extent(par[0]) - 1)
            + red[1] * (op.dim_extent(red[0]) - 1)
            + 1
        )
        total = max(0, needed - input_shape[ax])
        pads.append((total // 2, total - total // 2))
    return tuple(pads)


def window_geometry(op: GenericOp, info: KernelInfo | None = None) -> WindowGeometry:
    info = info or classify_kernel(op)
    if info.kernel_class != KernelClass.SLIDING_WINDOW:
        raise ValueError(f"{op.name} is not sliding-window")
    window_dims = info.classes.window
    # each composite expr in 𝒪 is s*i_p + δ*i_r: recover the reduction
    # extent paired with each window (parallel) dim, and the *original*
    # input extent s*(P-1) + δ*(R-1) + 1 along that axis.
    win_extents: dict[int, int] = {}
    in_extents: dict[int, int] = {}
    for expr in info.classes.original_input:
        par_dim = red_dim = None
        for d, c in expr.terms:
            if op.is_parallel_dim(d):
                par_dim = (d, c)
            else:
                red_dim = (d, c)
        if par_dim is None or red_dim is None:
            continue
        (pd, s), (rd, dil) = par_dim, red_dim
        win_extents[pd] = op.dim_extent(rd)
        in_extents[pd] = s * (op.dim_extent(pd) - 1) + dil * (op.dim_extent(rd) - 1) + 1
    return WindowGeometry(
        window_dims=window_dims,
        window_extents=tuple(win_extents.get(d, 1) for d in window_dims),
        input_extents=tuple(in_extents.get(d, 1) for d in window_dims),
        stride=info.stride,
        dilation=info.dilation,
    )
