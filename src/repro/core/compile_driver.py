"""Unified compile driver: one entry point, one schedule IR.

PR 1 left two ad-hoc lowering paths: the monolithic
``plan_streams → solve_ilp → emit_cpp`` chain for graphs that fit, and
``partition_layer_groups → emit_partitioned`` for graphs that don't —
with every consumer (HLS emitter, paper tables, Pallas wrappers)
re-deriving plan state on its own.  This module replaces both with an
explicit **schedule IR**:

* :class:`GroupSchedule` — one sequentially-executed slice of the graph:
  its subgraph, streaming plan, ILP solution (unrolls, stream widths,
  weight tiles), spill edges, and modeled cycles.
* :class:`CompiledDesign` — the ordered list of ``GroupSchedule``s plus
  the spill ledger and whole-design accounting.  A single-group design
  is just the degenerate case (``partitioned == False``).
* :func:`compile_design` — ``compile_design(dfg, target) ->
  CompiledDesign``: pass pipeline → cycle-balanced partitioning →
  per-group streaming + DSE.  (The historical ``compile`` alias
  finished its deprecation cycle and was removed in ISSUE 5; accessing
  it raises an ``AttributeError`` that names the new entry point.)
* :class:`CompileOptions` — the one frozen knob bundle (target preset
  or custom :class:`Target`, partition strategy, pass-pipeline
  selection, weight-streaming policy, DSE unroll cap), validated at
  construction and threaded through the driver, the partition DP
  (``repro.passes.partition``), and the ILP
  (``repro.core.dse.solve_ilp``) instead of loose positional kwargs.

Every backend works off the one ``CompiledDesign``:
``repro.core.emit_hls.emit_design`` (Vitis C++, one kernel per group +
host schedule), ``repro.kernels.ops.run_compiled`` (one fused Pallas/XLA
executable per group), and ``benchmarks/paper_tables`` (reporting).
The user-facing handle wrapping all of this is
``repro.api.CompiledArtifact``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional, TYPE_CHECKING

import repro.instrument as instrument

from .dse import DseResult
from .ir import DFG
from .resource_model import (
    DRAM_BYTES_PER_CYCLE,
    FpgaResourceModel,
    KV260_BRAM18K,
    KV260_DSP,
    ZU3EG_BRAM18K,
    ZU3EG_DSP,
    transition_cycles,
)
from .streaming import StreamingPlan

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids core→passes cycle
    from repro.passes.base import PipelineResult


# ---------------------------------------------------------------------------
# Targets
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Target:
    """A device budget the driver compiles against."""

    name: str = "kv260"
    d_total: int = KV260_DSP
    b_total: int = KV260_BRAM18K
    max_unroll: int = 4096

    def model(self) -> FpgaResourceModel:
        return FpgaResourceModel()


KV260 = Target()
ZU3EG = Target(name="zu3eg", d_total=ZU3EG_DSP, b_total=ZU3EG_BRAM18K)

#: device presets the multi-target sweep iterates over
TARGETS: dict[str, Target] = {t.name: t for t in (KV260, ZU3EG)}


# ---------------------------------------------------------------------------
# CompileOptions: the one validated knob bundle
# ---------------------------------------------------------------------------

_STRATEGIES = ("balanced", "greedy")
_WEIGHT_STREAMING = ("auto", "off")
_LINT = ("warn", "error", "off")


@dataclass(frozen=True)
class CompileOptions:
    """Everything a compile can be configured with, validated up front.

    ``target``
        A :class:`Target` or a preset name from :data:`TARGETS`
        (``"kv260"`` / ``"zu3eg"``); names resolve at construction.
    ``strategy``
        Partitioner: ``"balanced"`` (min-max DP) or ``"greedy"``
        (PR 1 prefix cut, kept for regression comparison).
    ``passes``
        Pass-pipeline selection: ``None`` → the default pipeline;
        ``()`` → skip rewrites entirely; a tuple of registry names
        (``repro.passes.PASS_REGISTRY``) → that exact pipeline, in that
        order.  Unknown names fail here, not mid-compile.
    ``weight_streaming``
        ``"auto"`` (the partitioner may re-solve over-budget slices
        with DRAM-streamed weight tiles) or ``"off"`` (resident weights
        only — graphs like ``fat_conv`` then raise
        :class:`~repro.passes.partition.PartitionError`).
    ``max_unroll``
        DSE search cap per node; ``None`` defers to the target's
        ``max_unroll``.
    ``verify``
        Run the structural verifier between passes (PassManager
        contract); only worth disabling in tight benchmark loops.
    ``trace``
        Instrumentation (ISSUE 6): ``False`` (default) compiles with
        the ambient tracer (usually the no-op null tracer — zero
        observable effect); ``True`` attaches a fresh
        :class:`repro.instrument.Tracer` to the design so pass/DP/DSE
        spans and runtime counters are collected; a string path does
        the same and is where the CLI writes the Chrome trace JSON.
        Tracing never changes schedules, emitted HLS, or BENCH metrics.
    ``lint``
        Static analysis (ISSUE 9): ``"warn"`` (default) runs the
        ``repro.analyze`` diagnostics engine over the compiled design
        and stores the findings on ``CompiledDesign.diagnostics``
        (surfaced through ``Report`` telemetry and ``python -m repro
        lint``); ``"error"`` additionally fails the compile with
        :class:`repro.analyze.LintError` when any ERROR-severity
        diagnostic fires; ``"off"`` skips the analyzer entirely.
        Like ``trace``, linting never changes the schedule.
    """

    target: Target | str = "kv260"
    strategy: str = "balanced"
    passes: Optional[tuple[str, ...]] = None
    weight_streaming: str = "auto"
    max_unroll: Optional[int] = None
    verify: bool = True
    trace: bool | str = False
    lint: str = "warn"

    def __post_init__(self) -> None:
        t = self.target
        if isinstance(t, str):
            if t not in TARGETS:
                raise ValueError(
                    f"unknown target preset {t!r} — available: "
                    f"{sorted(TARGETS)} (or pass a repro.core.Target)"
                )
            object.__setattr__(self, "target", TARGETS[t])
        elif not isinstance(t, Target):
            raise ValueError(
                f"target must be a Target or preset name, got "
                f"{type(t).__name__}"
            )
        if self.strategy not in _STRATEGIES:
            raise ValueError(
                f"unknown partition strategy {self.strategy!r} — "
                f"one of {_STRATEGIES}"
            )
        if self.weight_streaming not in _WEIGHT_STREAMING:
            raise ValueError(
                f"weight_streaming must be one of {_WEIGHT_STREAMING}, "
                f"got {self.weight_streaming!r}"
            )
        if self.max_unroll is not None and self.max_unroll < 1:
            raise ValueError(f"max_unroll must be >= 1, got {self.max_unroll}")
        if not isinstance(self.trace, (bool, str)):
            raise ValueError(
                f"trace must be False, True, or an output path, got "
                f"{type(self.trace).__name__}"
            )
        if isinstance(self.trace, str) and not self.trace:
            raise ValueError(
                "trace='' is ambiguous — use trace=False to disable or "
                "trace=True to collect without writing"
            )
        if self.lint not in _LINT:
            raise ValueError(
                f"lint must be one of {_LINT}, got {self.lint!r}"
            )
        if self.passes is not None:
            names = tuple(self.passes)
            object.__setattr__(self, "passes", names)
            from repro.passes import validate_pass_names

            validate_pass_names(names)

    # -- identity ------------------------------------------------------------

    def cache_key(self) -> str:
        """A stable, hashable digest of everything that determines the
        *compiled design*: the resolved target budgets, partition
        strategy, pass selection, weight-streaming policy, unroll cap,
        and verify flag.  ``trace`` and ``lint`` are deliberately
        excluded — neither instrumentation nor the diagnostics engine
        changes schedules (pinned by ``tests/test_instrument.py`` /
        ``tests/test_analyze.py``), so traced/linted and plain compiles
        share cache entries.  (A ``lint="error"`` rejection produces no
        design, so nothing stale can be cached.)

        This is *the* key for compiled-artifact caching: the serving
        artifact LRU (``repro.serve.ArtifactCache``) and the
        ``REPRO_BENCH_CACHE`` disk cache both key on
        ``(model name, options.cache_key())`` instead of ad-hoc target
        names, so an option change can never serve a stale design.
        """
        import hashlib

        t = self.target
        payload = (
            "ck1",  # bumped when the digest's field set changes
            t.name, t.d_total, t.b_total, t.max_unroll,
            self.strategy, self.passes, self.weight_streaming,
            self.max_unroll, self.verify,
        )
        return hashlib.sha256(repr(payload).encode()).hexdigest()[:16]

    # -- resolved views ------------------------------------------------------

    @property
    def resolved_max_unroll(self) -> int:
        return self.max_unroll if self.max_unroll is not None \
            else self.target.max_unroll

    @property
    def trace_path(self) -> Optional[str]:
        """The trace output path when ``trace`` names one, else None."""
        return self.trace if isinstance(self.trace, str) else None

    def run_pipeline(self, dfg: DFG):
        """Run the selected pass pipeline over ``dfg`` (clone-first, as
        PassManager always does).  Returns a ``PipelineResult`` or
        ``None`` when ``passes == ()``."""
        from repro.passes import (
            PassManager,
            pipeline_from_names,
            run_default_pipeline,
        )

        if self.passes is None:
            return run_default_pipeline(dfg, verify=self.verify)
        if not self.passes:
            return None
        pm = PassManager(pipeline_from_names(self.passes), verify=self.verify)
        return pm.run(dfg)


# ---------------------------------------------------------------------------
# Schedule IR
# ---------------------------------------------------------------------------


@dataclass
class SpillBuffer:
    """A DRAM buffer carrying one value across a group boundary."""

    value: str
    bits: int

    @property
    def bytes(self) -> int:
        return math.ceil(self.bits / 8)


@dataclass
class GroupSchedule:
    """One sequentially-executed slice of the graph, independently
    planned through streaming + DSE.  The unit every backend consumes."""

    name: str
    dfg: DFG
    plan: StreamingPlan
    dse: DseResult
    spill_in: list[str] = field(default_factory=list)
    spill_out: list[str] = field(default_factory=list)

    @property
    def bram(self) -> int:
        return self.dse.bram_used

    @property
    def dsp(self) -> int:
        return self.dse.dsp_used

    @property
    def cycles(self) -> int:
        return self.dse.estimate.pipeline_cycles

    @property
    def weight_streamed(self) -> dict[str, int]:
        """Nodes mapped with partial weight streaming (node -> tiles)."""
        return dict(self.dse.weight_tiles)

    @property
    def node_names(self) -> list[str]:
        return [n.name for n in self.dfg.nodes]


def boundary_bytes(
    dfg: DFG, left: "GroupSchedule", right: "GroupSchedule"
) -> tuple[int, int]:
    """(write, read) bytes DMA'd at the ``left → right`` group
    transition — the one definition of boundary traffic, shared by the
    design's accounting and the partition DP's tie-break cost so the DP
    always optimizes the total it reports."""
    w = sum(math.ceil(dfg.values[v].total_bits / 8) for v in left.spill_out)
    r = sum(math.ceil(dfg.values[v].total_bits / 8) for v in right.spill_in)
    return w, r


@dataclass
class CompiledDesign:
    """The schedule IR root: ordered groups + spill ledger + budgets.

    ``source`` is the (post-pass-pipeline) graph the groups partition;
    ``original`` the pre-pipeline graph when :func:`compile` ran the
    passes.  Known to every backend; derived nowhere else.
    """

    source: DFG
    groups: list[GroupSchedule]
    d_total: int
    b_total: int
    whole_graph_feasible: bool
    target: Optional[Target] = None
    original: Optional[DFG] = None
    pass_result: Optional["PipelineResult"] = None
    #: the validated knob bundle this design was compiled under (None
    #: for designs built through the bare partitioner API)
    options: Optional[CompileOptions] = None
    #: partition-DP search statistics (states explored, memo hits,
    #: rejected cuts with reasons, final frontier) — always recorded by
    #: the partitioner, surfaced through Report/trace (ISSUE 6)
    dp_stats: Optional[dict] = field(default=None, repr=False, compare=False)
    #: the Tracer that observed this compile when CompileOptions.trace
    #: was set; CompiledArtifact re-installs it for run()/emit_hls() so
    #: runtime counters land in the same trace.  Never pickled.
    tracer: Optional[object] = field(default=None, repr=False, compare=False)
    #: static-analysis findings (``repro.analyze.Diagnostic``) collected
    #: when ``CompileOptions.lint`` is not "off"; surfaced through
    #: Report telemetry and ``python -m repro lint``
    diagnostics: list = field(default_factory=list, repr=False,
                              compare=False)

    def __getstate__(self):
        # a save()d design must not drag its trace along: traces are an
        # export (write_trace), not part of the schedule IR
        state = dict(self.__dict__)
        state["tracer"] = None
        return state

    # -- group-level accounting ---------------------------------------------

    @property
    def partitioned(self) -> bool:
        return len(self.groups) > 1

    @property
    def feasible(self) -> bool:
        return all(g.dse.feasible for g in self.groups)

    @property
    def max_bram(self) -> int:
        """Peak resident BRAM — one group occupies the fabric at a time."""
        return max(g.bram for g in self.groups)

    @property
    def max_dsp(self) -> int:
        return max(g.dsp for g in self.groups)

    @property
    def max_group_cycles(self) -> int:
        """The slowest group — the cycle-balanced partitioner's objective."""
        return max(g.cycles for g in self.groups)

    @property
    def weight_streamed(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for g in self.groups:
            out.update(g.weight_streamed)
        return out

    # -- spill ledger --------------------------------------------------------

    def spills(self) -> list[SpillBuffer]:
        seen: dict[str, SpillBuffer] = {}
        for g in self.groups:
            for v in g.spill_out:
                val = self.source.values[v]
                seen.setdefault(v, SpillBuffer(v, val.total_bits))
        return list(seen.values())

    @property
    def spill_bits(self) -> int:
        return sum(s.bits for s in self.spills())

    def boundary_traffic(self) -> list[tuple[int, int]]:
        """(write_bytes, read_bytes) DMA'd at each group→group
        transition: group *k* writes its ``spill_out`` while group
        *k+1*'s ``spill_in`` is read back — the two transfers overlap
        (see :func:`~repro.core.resource_model.transition_cycles`).
        A value that skips groups is written once at its producer's
        transition and read at each consuming group's fill."""
        return [
            boundary_bytes(self.source, g, nxt)
            for g, nxt in zip(self.groups, self.groups[1:])
        ]

    @property
    def spill_cycles(self) -> int:
        """Boundary DMA under the overlapped model: per transition,
        ``max(spill write, fill read)`` plus the exposed burst tail —
        not the PR 2 serial write-then-read round trip."""
        return sum(transition_cycles(w, r) for w, r in self.boundary_traffic())

    @property
    def serial_spill_cycles(self) -> int:
        """The PR 2 cost model: the same boundary transfers, charged
        serially (write completes before the read starts, no overlap).
        On single-consumer chains this equals PR 2's per-spill-value
        round trip exactly; with multi-consumer spills it charges one
        fill per consuming group (the overlap model's traffic, which
        PR 2 under-counted).  Kept as the regression baseline the
        overlapped model must never exceed."""
        return sum(
            math.ceil(w / DRAM_BYTES_PER_CYCLE)
            + math.ceil(r / DRAM_BYTES_PER_CYCLE)
            for w, r in self.boundary_traffic()
        )

    @property
    def total_cycles(self) -> int:
        """Sequential schedule: groups back-to-back plus spill traffic."""
        return sum(g.cycles for g in self.groups) + self.spill_cycles

    # -- host-visible schedule ----------------------------------------------

    def schedule(self) -> list[dict]:
        """Host-visible schedule rows (consumed by the emitter and the
        benchmark report)."""
        return [
            {
                "group": g.name,
                "nodes": g.node_names,
                "bram": g.bram,
                "dsp": g.dsp,
                "cycles": g.cycles,
                "spill_in": list(g.spill_in),
                "spill_out": list(g.spill_out),
                "weight_streamed": g.weight_streamed,
            }
            for g in self.groups
        ]


# ---------------------------------------------------------------------------
# The driver
# ---------------------------------------------------------------------------


def compile_design(
    dfg: DFG,
    target: Optional[Target | str] = None,
    *,
    options: Optional[CompileOptions] = None,
    strategy: Optional[str] = None,
    run_passes: Optional[bool] = None,
) -> CompiledDesign:
    """Lower ``dfg`` to a :class:`CompiledDesign`.

    Configuration comes from one :class:`CompileOptions` (preferred) or
    the legacy kwargs (``target`` / ``strategy`` / ``run_passes``),
    which are folded into an options bundle — mixing both is an error.

    Stages: (1) the selected pass pipeline (default: canonicalize /
    DCE / CSE / fusion); (2) whole-graph streaming + ILP; (3) if over
    budget resident, the cost-aware balanced partitioner
    (``repro.passes.partition``) — which may keep any slice whole with
    streamed weight tiles instead of cutting it (unless
    ``weight_streaming="off"``), pricing DRAM tile traffic against
    overlapped spill boundaries.
    """
    from repro.passes import partition_layer_groups

    if options is None:
        options = CompileOptions(
            target=target if target is not None else KV260,
            strategy=strategy if strategy is not None else "balanced",
            passes=() if run_passes is False else None,
        )
    elif target is not None or strategy is not None or run_passes is not None:
        raise ValueError(
            "pass either options=CompileOptions(...) or the legacy "
            "target/strategy/run_passes kwargs, not both"
        )

    # tracer lifecycle (ISSUE 6): options.trace attaches a fresh Tracer
    # unless one is already ambient (a CLI/benchmark harness driving
    # several compiles into one trace); with trace off, the ambient
    # tracer — normally the no-op NULL_TRACER — is used as-is, so the
    # disabled path is byte-identical to the uninstrumented one.
    ambient = instrument.current()
    owned = instrument.Tracer() if options.trace and not ambient.enabled \
        else None
    with instrument.use_tracer(owned):
        tracer = instrument.current()
        with tracer.span(f"compile:{dfg.name}", cat="compile",
                         args={"target": options.target.name,
                               "strategy": options.strategy}):
            pass_result = options.run_pipeline(dfg)
            lowered = pass_result.dfg if pass_result is not None else dfg
            design = partition_layer_groups(lowered, options=options)
            if options.lint != "off":
                from repro.analyze import LintError, Severity, analyze_design

                design.diagnostics = analyze_design(design)
                if options.lint == "error" and any(
                    d.severity is Severity.ERROR for d in design.diagnostics
                ):
                    raise LintError(design.diagnostics, graph=lowered.name)
    design.target = options.target
    design.original = dfg
    design.pass_result = pass_result
    design.options = options
    if tracer.enabled:
        design.tracer = tracer
    return design


def __getattr__(name: str):
    """The ``compile`` alias (PR 2's original driver name, which
    shadowed the Python builtin) finished its deprecation cycle in
    ISSUE 5: every caller was migrated to :func:`compile_design` in
    PR 4, and the alias is now gone rather than warning forever."""
    if name == "compile":
        raise AttributeError(
            "repro.core.compile_driver.compile was removed after its "
            "deprecation cycle — call compile_design(dfg, ...) (same "
            "semantics, no builtin shadowing)"
        )
    raise AttributeError(
        f"module {__name__!r} has no attribute {name!r}"
    )
