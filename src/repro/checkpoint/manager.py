"""Sharded, atomic, mesh-agnostic checkpointing with async writes.

Layout (one directory per step)::

    <root>/step_000042/
        manifest.json      # step, leaf index, shapes/dtypes, extra metadata
        arr_00000.npy ...  # one file per pytree leaf

Properties engineered for large-scale runs:

* **Atomicity** — writes go to ``step_N.tmp`` then ``os.rename`` to
  ``step_N``; a crash mid-write never corrupts the latest checkpoint and
  ``latest_step`` only ever sees committed directories.
* **Mesh-agnostic restore (elastic scaling)** — leaves are saved as full
  logical arrays; ``restore`` takes target shardings and ``device_put``s
  each leaf, so a checkpoint written on a (16,16) mesh restores onto
  (2,16,16), (8,), or a single device (tested in
  ``tests/test_checkpoint.py::test_elastic_remesh``).  On a multi-host
  pod the same layout is produced per-host from addressable shards; the
  gather here is the single-process specialization.
* **Async** — ``save_async`` snapshots to host memory synchronously
  (cheap) and does file I/O on a writer thread; ``wait`` joins before the
  next save to bound in-flight checkpoints at 1.
* **Bit-exact resume** — restart tests assert training losses are
  identical post-restore (tests/test_fault_tolerance.py).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

#: extension dtypes numpy can't round-trip through .npy — stored as raw
#: uint views with the logical dtype recorded in the manifest
_EXT_DTYPES = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _to_savable(arr: np.ndarray) -> tuple[np.ndarray, str]:
    """(storage array, logical dtype name)."""
    name = arr.dtype.name
    if name in _EXT_DTYPES:
        _, view = _EXT_DTYPES[name]
        return arr.view(view), name
    return arr, name


def _from_saved(arr: np.ndarray, logical: str) -> np.ndarray:
    if logical in _EXT_DTYPES:
        ext, _ = _EXT_DTYPES[logical]
        return arr.view(ext)
    return arr


def _leaf_paths(tree: Any) -> list[str]:
    paths = []
    for kp, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append(jax.tree_util.keystr(kp))
    return paths


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3) -> None:
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- paths ---------------------------------------------------------------

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:09d}")

    def latest_step(self) -> Optional[int]:
        steps = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.root, d, "manifest.json")):
                    steps.append(int(d.split("_")[1]))
        return max(steps) if steps else None

    # -- save ------------------------------------------------------------------

    def save(self, step: int, tree: Any, extra: dict | None = None) -> str:
        """Synchronous atomic save."""
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        return self._write(step, host_tree, extra or {})

    def save_async(self, step: int, tree: Any, extra: dict | None = None) -> None:
        """Snapshot now, write on a background thread.  The snapshot must
        COPY host-resident arrays — ``device_get`` is a no-op passthrough
        for numpy inputs, and the caller may mutate them before the
        writer thread runs (caught by test_async_snapshot_semantics)."""
        self.wait()
        host_tree = jax.tree.map(
            lambda x: np.array(jax.device_get(x), copy=True), tree
        )
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree, extra or {}), daemon=True
        )
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: Any, extra: dict) -> str:
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves, treedef = jax.tree.flatten(host_tree)
        manifest = {
            "step": step,
            "extra": extra,
            "leaf_paths": _leaf_paths(host_tree),
            "leaves": [],
        }
        for i, leaf in enumerate(leaves):
            fname = f"arr_{i:05d}.npy"
            storage, logical = _to_savable(np.asarray(leaf))
            np.save(os.path.join(tmp, fname), storage, allow_pickle=False)
            manifest["leaves"].append(
                {"file": fname, "shape": list(leaf.shape),
                 "dtype": logical}
            )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)           # commit point
        self._gc()
        return final

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.root)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore -----------------------------------------------------------------

    def restore(
        self,
        step: int,
        template: Any,
        shardings: Any | None = None,
    ) -> tuple[Any, dict]:
        """Restore into the structure of ``template``.

        ``shardings``: optional pytree (congruent with template) of
        ``jax.sharding.Sharding`` — enables restoring onto a different
        mesh than the one that saved (elastic re-mesh).
        """
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves_t, treedef = jax.tree.flatten(template)
        assert len(leaves_t) == len(manifest["leaves"]), (
            f"checkpoint has {len(manifest['leaves'])} leaves, template "
            f"{len(leaves_t)} — structure changed?"
        )
        shard_leaves = (
            jax.tree.leaves(shardings) if shardings is not None
            else [None] * len(leaves_t)
        )
        out = []
        for i, (meta, tmpl, shd) in enumerate(
            zip(manifest["leaves"], leaves_t, shard_leaves)
        ):
            arr = _from_saved(np.load(os.path.join(d, meta["file"])),
                              meta["dtype"])
            assert tuple(arr.shape) == tuple(tmpl.shape), (
                manifest["leaf_paths"][i], arr.shape, tmpl.shape)
            arr = arr.astype(tmpl.dtype)
            out.append(
                jax.device_put(arr, shd) if shd is not None else jnp.asarray(arr)
            )
        return treedef.unflatten(out), manifest["extra"]
