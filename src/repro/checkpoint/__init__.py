"""checkpoint substrate."""
