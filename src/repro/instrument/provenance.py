"""Run provenance: who/where/when a number came from.

``BENCH_smoke.json`` rows and exported traces are compared across runs,
machines, and PRs; a row without provenance is a number you cannot
trust a week later.  :func:`provenance` returns the stamp — git sha,
host, platform, python, wall-clock — that the benchmark harness attaches
to every row and the tracer embeds in ``otherData``.

The git sha is resolved once per process (``git rev-parse HEAD`` from
this file's repo, overridable via ``REPRO_GIT_SHA`` for environments
without a work tree) and never raises: a missing git binary degrades to
``"unknown"``, not a crashed benchmark run.
"""
from __future__ import annotations

import os
import platform
import subprocess
import sys
import time
from typing import Mapping, Optional

_GIT_SHA: Optional[str] = None


def git_sha() -> str:
    """The repo HEAD sha (cached; ``REPRO_GIT_SHA`` wins; ``"unknown"``
    when neither is available)."""
    global _GIT_SHA
    if _GIT_SHA is None:
        sha = os.environ.get("REPRO_GIT_SHA", "").strip()
        if not sha:
            try:
                sha = subprocess.run(
                    ["git", "rev-parse", "HEAD"],
                    cwd=os.path.dirname(os.path.abspath(__file__)),
                    capture_output=True, text=True, timeout=10,
                ).stdout.strip()
            except (OSError, subprocess.SubprocessError):
                sha = ""
        _GIT_SHA = sha or "unknown"
    return _GIT_SHA


def provenance(extra: Optional[Mapping] = None) -> dict:
    """The provenance stamp: stable identity fields plus ``extra``
    (per-row measurements like compile wall time / pass timings)."""
    out = {
        "git_sha": git_sha(),
        "host": platform.node() or "unknown",
        "platform": platform.platform(),
        "python": sys.version.split()[0],
        "time_unix": round(time.time(), 3),
    }
    if extra:
        out.update(extra)
    return out
