"""Live aggregated telemetry: a zero-dependency metrics registry.

The PR 6 tracer answers "what happened, in order" — a post-hoc Chrome
trace of one compile/run.  This module answers "what is happening,
in aggregate": labeled counters, gauges, and latency histograms that
a serving engine can update from its worker thread while a load
generator (or an operator) reads consistent snapshots from another.
Prometheus invented nothing here — this is the standard three-kind
model (counter / gauge / histogram with cumulative ``le`` buckets),
implemented dependency-free the way the rest of ``repro.instrument``
is, with the same governing contract as the tracer:

* every instrument is **thread-safe** (one registry lock covers
  update + snapshot — updates are a few dict ops, never worth a
  finer-grained scheme);
* :data:`NULL_REGISTRY` is the ambient default and a true no-op — a
  shared null instrument whose ``inc``/``set``/``observe`` do nothing,
  so uninstrumented runs allocate nothing and stay byte-identical
  (pinned by ``tests/test_metrics.py``, same discipline as
  :data:`repro.instrument.tracer.NULL_TRACER`);
* producers never import consumers: the registry knows nothing about
  engines or kernels.  The series the stack actually emits are
  documented in DESIGN.md §9.

Two export forms: :meth:`MetricsRegistry.snapshot` (a versioned,
JSON-serializable document — the ``BENCH_serve.json`` cells and the CI
artifact carry these) and :meth:`MetricsRegistry.to_prometheus` (the
text exposition format, so a future HTTP front end can serve
``/metrics`` verbatim).
"""
from __future__ import annotations

import contextlib
import contextvars
import math
import threading
from typing import Iterator, Mapping, Optional, Sequence

#: fixed exponential latency buckets (milliseconds): 0.25 ms … ~8.2 s,
#: doubling — wide enough to hold both a sub-ms vmapped dispatch and a
#: queue-collapsed open-loop p99, coarse enough that a snapshot stays
#: small.  Shared by every ``*_ms`` histogram the stack emits so
#: series are comparable across engines and runs.
LATENCY_BUCKETS_MS: tuple[float, ...] = tuple(
    0.25 * 2 ** k for k in range(16)
)

#: batch-occupancy buckets: the vmap bucket ladder (powers of two up to
#: the top :data:`repro.kernels.ops.BATCH_BUCKETS` extent)
BATCH_BUCKETS_SIZES: tuple[float, ...] = (1, 2, 4, 8, 16, 32, 64)

_KINDS = ("counter", "gauge", "histogram")


def _label_key(label_names: tuple[str, ...], labels: Mapping) -> tuple:
    """The child key for one label assignment, validated against the
    instrument's declared label names — a typo'd label must fail at the
    call site, not silently create a parallel series."""
    if set(labels) != set(label_names):
        raise ValueError(
            f"labels {sorted(labels)} do not match declared label names "
            f"{sorted(label_names)}"
        )
    return tuple(str(labels[n]) for n in label_names)


class _NullInstrument:
    """Shared no-op counter/gauge/histogram for the disabled path."""

    __slots__ = ()

    def inc(self, amount: float = 1.0, **labels) -> None:
        pass

    def dec(self, amount: float = 1.0, **labels) -> None:
        pass

    def set(self, value: float, **labels) -> None:
        pass

    def observe(self, value: float, **labels) -> None:
        pass

    def value(self, **labels) -> float:
        return 0.0


_NULL_INSTRUMENT = _NullInstrument()


class NullRegistry:
    """The ambient default: every instrument is the shared no-op.

    ``enabled`` is False so hot paths can skip even the cheap calls;
    everything else exists so call sites never branch on registry
    identity (the tracer's exact contract)."""

    enabled = False

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  buckets: Sequence[float] = LATENCY_BUCKETS_MS,
                  ) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def snapshot(self) -> dict:
        """An empty (but schema-valid) document, for export symmetry."""
        return {"version": 1, "counters": {}, "gauges": {}, "histograms": {}}

    def to_prometheus(self) -> str:
        return ""


NULL_REGISTRY = NullRegistry()


class _Instrument:
    """One named metric family: label names + per-label-set children.

    Subclasses define the child state and the update verbs.  All state
    mutation happens under the owning registry's lock — instruments are
    handed out once at construction and shared across threads."""

    kind = "base"

    def __init__(self, registry: "MetricsRegistry", name: str, help: str,
                 label_names: tuple[str, ...]) -> None:
        self._registry = registry
        self._lock = registry._lock
        self.name = name
        self.help = help
        self.label_names = label_names
        self._children: dict[tuple, object] = {}

    def _child(self, labels: Mapping):
        """Get-or-create the child slot for one label assignment.
        Callers hold the lock."""
        key = _label_key(self.label_names, labels)
        child = self._children.get(key)
        if child is None:
            child = self._new_child()
            self._children[key] = child
        return child

    def _new_child(self):  # pragma: no cover - abstract
        raise NotImplementedError

    def _export_children(self) -> list[dict]:
        out = []
        for key in sorted(self._children):
            row: dict = {"labels": dict(zip(self.label_names, key))}
            row.update(self._export_child(self._children[key]))
            out.append(row)
        return out

    def _export_child(self, child) -> dict:  # pragma: no cover - abstract
        raise NotImplementedError


class Counter(_Instrument):
    """A monotonically increasing total (requests served, rejections by
    cause).  Decrementing is an error — that is what gauges are for."""

    kind = "counter"

    def _new_child(self) -> list:
        return [0.0]

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(
                f"counter {self.name}: inc({amount}) — counters only go up"
            )
        with self._lock:
            self._child(labels)[0] += amount

    def value(self, **labels) -> float:
        with self._lock:
            key = _label_key(self.label_names, labels)
            child = self._children.get(key)
            return child[0] if child else 0.0

    def total(self) -> float:
        """The sum over every label assignment."""
        with self._lock:
            return sum(c[0] for c in self._children.values())

    def _export_child(self, child) -> dict:
        return {"value": child[0]}


class Gauge(_Instrument):
    """A value that goes both ways (queue depth, in-flight batches)."""

    kind = "gauge"

    def _new_child(self) -> list:
        return [0.0]

    def set(self, value: float, **labels) -> None:
        with self._lock:
            self._child(labels)[0] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        with self._lock:
            self._child(labels)[0] += amount

    def dec(self, amount: float = 1.0, **labels) -> None:
        self.inc(-amount, **labels)

    def value(self, **labels) -> float:
        with self._lock:
            key = _label_key(self.label_names, labels)
            child = self._children.get(key)
            return child[0] if child else 0.0

    def _export_child(self, child) -> dict:
        return {"value": child[0]}


class Histogram(_Instrument):
    """A distribution over fixed buckets (latency, batch occupancy).

    Buckets are **upper bounds** with Prometheus ``le`` semantics: an
    observation lands in every bucket whose bound is ≥ the value
    (cumulative counts), with an implicit ``+Inf`` bucket equal to the
    total count.  Bounds are fixed at construction — exponential
    latency ladders by default — so merging/diffing snapshots never
    has to re-bucket."""

    kind = "histogram"

    def __init__(self, registry, name, help, label_names,
                 buckets: Sequence[float]) -> None:
        super().__init__(registry, name, help, label_names)
        bounds = tuple(float(b) for b in buckets)
        if not bounds:
            raise ValueError(f"histogram {name}: needs at least one bucket")
        if any(b2 <= b1 for b1, b2 in zip(bounds, bounds[1:])):
            raise ValueError(
                f"histogram {name}: bucket bounds must strictly increase, "
                f"got {bounds}"
            )
        if any(not math.isfinite(b) for b in bounds):
            raise ValueError(
                f"histogram {name}: bounds must be finite (+Inf is "
                f"implicit), got {bounds}"
            )
        self.buckets = bounds

    def _new_child(self) -> dict:
        return {"counts": [0] * len(self.buckets), "inf": 0,
                "sum": 0.0, "count": 0, "min": None, "max": None}

    def observe(self, value: float, **labels) -> None:
        v = float(value)
        with self._lock:
            c = self._child(labels)
            c["sum"] += v
            c["count"] += 1
            c["min"] = v if c["min"] is None else min(c["min"], v)
            c["max"] = v if c["max"] is None else max(c["max"], v)
            # non-cumulative per-bucket counts internally; snapshot
            # accumulates them into le-form so hot-path observes stay O(1)
            for i, bound in enumerate(self.buckets):
                if v <= bound:
                    c["counts"][i] += 1
                    return
            c["inf"] += 1

    def value(self, **labels) -> float:
        """The observation count (symmetry with counter/gauge)."""
        with self._lock:
            key = _label_key(self.label_names, labels)
            child = self._children.get(key)
            return child["count"] if child else 0.0

    def _export_child(self, child) -> dict:
        cum = []
        running = 0
        for bound, n in zip(self.buckets, child["counts"]):
            running += n
            cum.append({"le": bound, "count": running})
        cum.append({"le": "+Inf", "count": running + child["inf"]})
        return {
            "count": child["count"],
            "sum": round(child["sum"], 6),
            "min": child["min"],
            "max": child["max"],
            "buckets": cum,
        }


def quantile(hist_row: Mapping, q: float) -> float:
    """Estimate the ``q``-quantile (0..100) from one exported histogram
    row (``{"count": ..., "buckets": [{"le": ..., "count": ...}]}``) by
    linear interpolation within the landing bucket — the standard
    ``histogram_quantile`` estimate.  Returns 0.0 for empty rows; the
    ``+Inf`` bucket clamps to the largest finite bound (or the observed
    ``max`` when present)."""
    if not 0 <= q <= 100:
        raise ValueError(f"quantile must be in [0, 100], got {q}")
    total = hist_row.get("count", 0)
    buckets = hist_row.get("buckets") or []
    if not total or not buckets:
        return 0.0
    rank = q / 100.0 * total
    prev_bound, prev_count = 0.0, 0
    for b in buckets:
        bound, count = b["le"], b["count"]
        if bound == "+Inf":
            mx = hist_row.get("max")
            return float(mx if mx is not None else prev_bound)
        if count >= rank:
            if count == prev_count:
                return float(bound)
            frac = (rank - prev_count) / (count - prev_count)
            return float(prev_bound + frac * (bound - prev_bound))
        prev_bound, prev_count = bound, count
    return float(prev_bound)


class MetricsRegistry:
    """Threadsafe home of one process-area's instruments.

    Instruments are created once (``counter``/``gauge``/``histogram``
    are get-or-create: re-declaring the same name with the same kind
    and labels returns the existing instrument; with different ones it
    raises) and updated from any thread.  ``snapshot()`` returns a
    consistent point-in-time JSON document; ``to_prometheus()`` the
    text exposition."""

    enabled = True

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._instruments: dict[str, _Instrument] = {}

    # -- declaration ---------------------------------------------------------

    def _declare(self, cls, name: str, help: str,
                 label_names: tuple[str, ...], **kwargs):
        if not name or not isinstance(name, str):
            raise ValueError(f"metric name must be a non-empty string, "
                             f"got {name!r}")
        with self._lock:
            existing = self._instruments.get(name)
            if existing is not None:
                if (type(existing) is not cls
                        or existing.label_names != label_names
                        or kwargs.get("buckets") is not None
                        and getattr(existing, "buckets", None)
                        != tuple(float(b) for b in kwargs["buckets"])):
                    raise ValueError(
                        f"metric {name!r} already declared as "
                        f"{existing.kind} with labels "
                        f"{existing.label_names}"
                    )
                return existing
            inst = cls(self, name, help, label_names, **kwargs)
            self._instruments[name] = inst
            return inst

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Counter:
        return self._declare(Counter, name, help, tuple(labels))

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Gauge:
        return self._declare(Gauge, name, help, tuple(labels))

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  buckets: Sequence[float] = LATENCY_BUCKETS_MS,
                  ) -> Histogram:
        return self._declare(Histogram, name, help, tuple(labels),
                             buckets=buckets)

    # -- export --------------------------------------------------------------

    def snapshot(self) -> dict:
        """A consistent point-in-time export: ``{"version": 1,
        "counters": {...}, "gauges": {...}, "histograms": {...}}``,
        every leaf JSON-serializable (validated shape — see
        :func:`validate_metrics_snapshot`)."""
        with self._lock:
            doc: dict = {"version": 1, "counters": {}, "gauges": {},
                         "histograms": {}}
            for name, inst in sorted(self._instruments.items()):
                entry: dict = {
                    "help": inst.help,
                    "labels": list(inst.label_names),
                    "values": inst._export_children(),
                }
                if isinstance(inst, Histogram):
                    entry["buckets"] = list(inst.buckets)
                doc[inst.kind + "s"][name] = entry
            return doc

    def to_prometheus(self) -> str:
        """The Prometheus text exposition format (version 0.0.4):
        ``# HELP`` / ``# TYPE`` headers, one sample line per child,
        histograms expanded to ``_bucket{le=...}`` / ``_sum`` /
        ``_count`` series."""
        snap = self.snapshot()
        lines: list[str] = []

        def fmt_labels(labels: Mapping, extra: Optional[dict] = None) -> str:
            items = dict(labels)
            if extra:
                items.update(extra)
            if not items:
                return ""
            inner = ",".join(
                f'{k}="{_escape(str(v))}"' for k, v in items.items()
            )
            return "{" + inner + "}"

        def _escape(s: str) -> str:
            return s.replace("\\", r"\\").replace('"', r"\"") \
                    .replace("\n", r"\n")

        for kind in _KINDS:
            for name, entry in snap[kind + "s"].items():
                if entry["help"]:
                    lines.append(f"# HELP {name} {entry['help']}")
                lines.append(f"# TYPE {name} {kind}")
                for row in entry["values"]:
                    if kind == "histogram":
                        for b in row["buckets"]:
                            le = ("+Inf" if b["le"] == "+Inf"
                                  else repr(float(b["le"])))
                            lines.append(
                                f"{name}_bucket"
                                f"{fmt_labels(row['labels'], {'le': le})} "
                                f"{b['count']}"
                            )
                        lines.append(
                            f"{name}_sum{fmt_labels(row['labels'])} "
                            f"{row['sum']}"
                        )
                        lines.append(
                            f"{name}_count{fmt_labels(row['labels'])} "
                            f"{row['count']}"
                        )
                    else:
                        lines.append(
                            f"{name}{fmt_labels(row['labels'])} "
                            f"{row['value']}"
                        )
        return "\n".join(lines) + ("\n" if lines else "")


# ---------------------------------------------------------------------------
# Ambient registry (contextvar-threaded, the tracer's exact pattern)
# ---------------------------------------------------------------------------

_CURRENT: contextvars.ContextVar = contextvars.ContextVar(
    "repro_metrics", default=NULL_REGISTRY
)


def current():
    """The ambient registry — :data:`NULL_REGISTRY` unless
    :func:`use_metrics` is active on this context."""
    return _CURRENT.get()


@contextlib.contextmanager
def use_metrics(registry) -> Iterator:
    """Install ``registry`` as the ambient metrics registry for the
    dynamic extent.  ``None`` (or the already-installed registry) is a
    no-op scope, mirroring :func:`repro.instrument.use_tracer`."""
    if registry is None or registry is _CURRENT.get():
        yield registry
        return
    token = _CURRENT.set(registry)
    try:
        yield registry
    finally:
        _CURRENT.reset(token)


# ---------------------------------------------------------------------------
# Snapshot schema validation (the CI artifact gate)
# ---------------------------------------------------------------------------


def validate_metrics_snapshot(obj) -> dict:
    """Validate a :meth:`MetricsRegistry.snapshot` document.  Raises
    :class:`ValueError` naming the first offence; returns ``obj``
    unchanged on success — the same contract as
    :func:`repro.instrument.validate_chrome_trace`."""
    if not isinstance(obj, dict):
        raise ValueError(
            f"metrics snapshot: expected dict, got {type(obj).__name__}"
        )
    if obj.get("version") != 1:
        raise ValueError(
            f"metrics snapshot: unknown version {obj.get('version')!r}"
        )
    for kind in _KINDS:
        section = obj.get(kind + "s")
        if not isinstance(section, dict):
            raise ValueError(f"metrics snapshot: missing {kind}s section")
        for name, entry in section.items():
            where = f"metrics snapshot: {kind} {name!r}"
            if not isinstance(entry, dict):
                raise ValueError(f"{where} is not an object")
            if not isinstance(entry.get("labels"), list):
                raise ValueError(f"{where}: missing labels list")
            values = entry.get("values")
            if not isinstance(values, list):
                raise ValueError(f"{where}: missing values list")
            for row in values:
                if not isinstance(row.get("labels"), dict):
                    raise ValueError(f"{where}: row missing labels dict")
                if sorted(row["labels"]) != sorted(entry["labels"]):
                    raise ValueError(
                        f"{where}: row labels {sorted(row['labels'])} != "
                        f"declared {sorted(entry['labels'])}"
                    )
                if kind == "histogram":
                    for k in ("count", "sum", "buckets"):
                        if k not in row:
                            raise ValueError(f"{where}: row missing {k!r}")
                    buckets = row["buckets"]
                    if not buckets or buckets[-1]["le"] != "+Inf":
                        raise ValueError(
                            f"{where}: bucket list must end with +Inf"
                        )
                    counts = [b["count"] for b in buckets]
                    if counts != sorted(counts):
                        raise ValueError(
                            f"{where}: bucket counts must be cumulative"
                        )
                    if counts[-1] != row["count"]:
                        raise ValueError(
                            f"{where}: +Inf count {counts[-1]} != "
                            f"count {row['count']}"
                        )
                else:
                    if not isinstance(row.get("value"), (int, float)):
                        raise ValueError(f"{where}: row missing numeric value")
    return obj
