"""Span tracing + metrics for the compile/run stack (zero-dependency).

MLIR ships its automation with instrumentation — ``-mlir-timing``,
``-print-ir-after-all``, pass statistics — and this module is our
equivalent, one layer the whole stack threads through:

* :class:`Tracer` — span-based (monotonic clock, nestable), plus
  instant events and counter samples, accumulated as Chrome
  trace-event dicts (the ``chrome://tracing`` / Perfetto format, see
  :func:`validate_chrome_trace`).
* a :mod:`contextvars` ambient slot — :func:`use_tracer` installs a
  tracer for a dynamic extent, :func:`current` reads it.  When nothing
  is installed, :data:`NULL_TRACER` is returned: every operation is a
  true no-op (shared null span, discarded args), so uninstrumented
  runs stay byte-identical in output and pay no event allocation.

Producers never import consumers: the tracer knows nothing about the
IR, passes, or kernels — they call ``current().span(...)`` /
``instant`` / ``counter`` and attach whatever args they like.  The
taxonomy actually emitted by the stack is documented in DESIGN.md §6.
"""
from __future__ import annotations

import contextlib
import contextvars
import json
import time
from typing import Any, Callable, Iterator, Mapping, Optional

#: categories the stack emits (informative, not enforced — see DESIGN.md §6)
CATEGORIES = ("compile", "passes", "partition", "analyze", "dse", "emit",
              "runtime")

#: Chrome trace-event phases this layer produces (and the validator's
#: accepted superset — "B"/"E" pairs appear in externally-merged traces)
_PHASES = {"X", "i", "I", "C", "M", "B", "E"}


class _DiscardDict(dict):
    """A write-sink: the null span hands this out so callers can attach
    span args unconditionally without the disabled path accumulating
    anything (or allocating a fresh dict per span)."""

    def __setitem__(self, key, value):  # pragma: no cover - trivial
        pass

    def update(self, *a, **kw):
        pass


_DISCARD = _DiscardDict()


class _NullSpan:
    """Shared no-op context manager for the disabled path."""

    __slots__ = ()

    def __enter__(self) -> Mapping:
        return _DISCARD

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """The ambient default: every call is a no-op.

    ``enabled`` is False so hot loops can skip even the cheap calls
    (``if tracer.enabled: ...``); everything else exists so call sites
    never branch on tracer identity.
    """

    enabled = False
    ir_snapshots = False

    def span(self, name: str, *, cat: str = "compile",
             args: Optional[Mapping] = None) -> _NullSpan:
        return _NULL_SPAN

    def instant(self, name: str, *, cat: str = "compile",
                args: Optional[Mapping] = None) -> None:
        pass

    def counter(self, name: str, values: Mapping[str, float], *,
                cat: str = "runtime") -> None:
        pass

    def to_chrome(self, *, provenance: Optional[Mapping] = None) -> dict:
        """An empty (but schema-valid) trace, for export symmetry."""
        return {"traceEvents": [], "displayTimeUnit": "ms", "otherData": {}}


NULL_TRACER = NullTracer()


class Tracer:
    """Collects Chrome trace events against one monotonic time base.

    ``span(name)`` is a context manager timing its body as a complete
    ("X") event; it yields the event's ``args`` dict so the body can
    attach statistics discovered *during* the span::

        with tracer.span("pass:fusion", cat="passes") as args:
            stats = run()
            args.update(stats)

    Spans nest naturally (same pid/tid, enclosing ts/dur).  ``instant``
    records a point event carrying structured args (the DP search
    statistics ride one of these); ``counter`` records a sampled value
    series (jit-cache hits, DMA bytes).

    ``ir_snapshots=True`` asks the PassManager for
    ``-print-ir-after-all`` behaviour: a structural snapshot + diff per
    pass (see :mod:`repro.instrument.snapshot`) attached to the pass's
    ``ir_after`` instant events.
    """

    enabled = True

    def __init__(self, *, ir_snapshots: bool = False,
                 clock: Callable[[], int] = time.perf_counter_ns) -> None:
        self.ir_snapshots = ir_snapshots
        self.events: list[dict] = []
        self._clock = clock
        self._t0 = clock()
        self.meta: dict[str, Any] = {}

    # -- time base -----------------------------------------------------------

    def _us(self, t_ns: int) -> float:
        """Nanoseconds-since-epoch → µs relative to tracer start (the
        Chrome trace ``ts`` unit)."""
        return round((t_ns - self._t0) / 1e3, 3)

    def now_us(self) -> float:
        return self._us(self._clock())

    # -- event producers -----------------------------------------------------

    @contextlib.contextmanager
    def _span_cm(self, name: str, cat: str,
                 args: Optional[Mapping]) -> Iterator[dict]:
        payload: dict = dict(args) if args else {}
        t0 = self._clock()
        try:
            yield payload
        finally:
            t1 = self._clock()
            self.events.append({
                "name": name, "cat": cat, "ph": "X",
                "ts": self._us(t0),
                "dur": round((t1 - t0) / 1e3, 3),
                "pid": 1, "tid": 1, "args": payload,
            })

    def span(self, name: str, *, cat: str = "compile",
             args: Optional[Mapping] = None):
        return self._span_cm(name, cat, args)

    def instant(self, name: str, *, cat: str = "compile",
                args: Optional[Mapping] = None) -> None:
        self.events.append({
            "name": name, "cat": cat, "ph": "i", "s": "t",
            "ts": self.now_us(), "pid": 1, "tid": 1,
            "args": dict(args) if args else {},
        })

    def counter(self, name: str, values: Mapping[str, float], *,
                cat: str = "runtime") -> None:
        self.events.append({
            "name": name, "cat": cat, "ph": "C",
            "ts": self.now_us(), "pid": 1, "tid": 1,
            "args": {k: float(v) for k, v in values.items()},
        })

    # -- export --------------------------------------------------------------

    def to_chrome(self, *, provenance: Optional[Mapping] = None) -> dict:
        """The full Chrome trace-event JSON object (validated shape —
        see :func:`validate_chrome_trace`)."""
        other = dict(self.meta)
        if provenance:
            other["provenance"] = dict(provenance)
        return {
            "traceEvents": list(self.events),
            "displayTimeUnit": "ms",
            "otherData": other,
        }

    def write(self, path: str, *, provenance: Optional[Mapping] = None) -> str:
        obj = self.to_chrome(provenance=provenance)
        validate_chrome_trace(obj)  # never write an invalid trace
        with open(path, "w") as f:
            json.dump(obj, f, indent=1)
        return path


# ---------------------------------------------------------------------------
# Ambient tracer (contextvar-threaded, per ISSUE 6's byte-identity clause)
# ---------------------------------------------------------------------------

_CURRENT: contextvars.ContextVar = contextvars.ContextVar(
    "repro_tracer", default=NULL_TRACER
)


def current():
    """The ambient tracer — :data:`NULL_TRACER` unless :func:`use_tracer`
    is active on this context."""
    return _CURRENT.get()


def tracing_active() -> bool:
    return _CURRENT.get().enabled


@contextlib.contextmanager
def use_tracer(tracer) -> Iterator:
    """Install ``tracer`` as the ambient tracer for the dynamic extent.

    Passing ``None`` (or an already-installed tracer) is a no-op scope,
    so call sites can write ``with use_tracer(maybe_tracer):``
    unconditionally."""
    if tracer is None or tracer is _CURRENT.get():
        yield tracer
        return
    token = _CURRENT.set(tracer)
    try:
        yield tracer
    finally:
        _CURRENT.reset(token)


# module-level conveniences: operate on the ambient tracer
def span(name: str, *, cat: str = "compile", args: Optional[Mapping] = None):
    return _CURRENT.get().span(name, cat=cat, args=args)


def instant(name: str, *, cat: str = "compile",
            args: Optional[Mapping] = None) -> None:
    _CURRENT.get().instant(name, cat=cat, args=args)


def counter(name: str, values: Mapping[str, float], *,
            cat: str = "runtime") -> None:
    _CURRENT.get().counter(name, values, cat=cat)


# ---------------------------------------------------------------------------
# Chrome trace-event schema validation
# ---------------------------------------------------------------------------


def validate_chrome_trace(obj) -> dict:
    """Validate ``obj`` against the Chrome trace-event format (the JSON
    Object Format: ``{"traceEvents": [...]}``; a bare event array is
    also accepted, per the spec).  Raises :class:`ValueError` naming the
    first offending event; returns the object unchanged on success.

    Checked per event: ``name``/``cat``/``ph`` strings, ``ph`` a known
    phase, numeric non-negative ``ts`` (and ``dur`` for complete
    events), ``pid``/``tid`` integers, ``args`` a dict when present —
    the fields ``chrome://tracing`` and Perfetto actually require to
    render the event.
    """
    if isinstance(obj, list):
        events = obj
    elif isinstance(obj, dict):
        events = obj.get("traceEvents")
        if not isinstance(events, list):
            raise ValueError(
                "chrome trace: top-level object needs a 'traceEvents' list"
            )
    else:
        raise ValueError(
            f"chrome trace: expected dict or list, got {type(obj).__name__}"
        )
    for i, ev in enumerate(events):
        where = f"chrome trace: event[{i}]"
        if not isinstance(ev, dict):
            raise ValueError(f"{where} is not an object")
        for key in ("name", "ph"):
            if not isinstance(ev.get(key), str) or not ev[key]:
                raise ValueError(f"{where}: missing/empty string {key!r}")
        if ev["ph"] not in _PHASES:
            raise ValueError(
                f"{where} ({ev['name']!r}): unknown phase {ev['ph']!r}"
            )
        if ev["ph"] != "M":  # metadata events carry no timestamp
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)) or ts < 0:
                raise ValueError(
                    f"{where} ({ev['name']!r}): bad ts {ts!r}"
                )
        if ev["ph"] == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(
                    f"{where} ({ev['name']!r}): complete event needs "
                    f"numeric dur >= 0, got {dur!r}"
                )
        for key in ("pid", "tid"):
            if key in ev and not isinstance(ev[key], int):
                raise ValueError(
                    f"{where} ({ev['name']!r}): {key} must be an int"
                )
        if "args" in ev and not isinstance(ev["args"], dict):
            raise ValueError(
                f"{where} ({ev['name']!r}): args must be an object"
            )
        if ev["ph"] == "C":
            args = ev.get("args") or {}
            bad = [k for k, v in args.items()
                   if not isinstance(v, (int, float))]
            if bad:
                raise ValueError(
                    f"{where} ({ev['name']!r}): counter args must be "
                    f"numeric (bad: {bad})"
                )
    return obj
