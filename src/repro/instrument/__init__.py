"""Compiler & runtime instrumentation (ISSUE 6/10) — zero-dependency.

One layer, five pieces:

* :mod:`repro.instrument.tracer` — the span/instant/counter
  :class:`Tracer`, the ambient contextvar slot (:func:`use_tracer` /
  :func:`current`), and Chrome trace-event export + validation;
* :mod:`repro.instrument.metrics` — live aggregated telemetry: the
  labeled Counter/Gauge/Histogram :class:`MetricsRegistry` with JSON
  snapshots and Prometheus-text exposition, its own ambient slot
  (:func:`use_metrics` / :func:`metrics_current`), and
  :data:`NULL_REGISTRY`;
* :mod:`repro.instrument.profiler` — the modeled-vs-measured join:
  run a compiled artifact and reconcile per-group wall times against
  the resource model's cycle predictions;
* :mod:`repro.instrument.snapshot` — structural DFG snapshots and
  diffs (``-print-ir-after-all``);
* :mod:`repro.instrument.provenance` — git-sha/host/time stamps for
  BENCH rows and exported traces.

The contract that makes this safe to thread everywhere: with no tracer
installed and :data:`NULL_REGISTRY` ambient, every entry point here is
a true no-op and instrumented code produces byte-identical output
(pinned by ``tests/test_instrument.py`` and ``tests/test_metrics.py``).
"""
from .metrics import (
    LATENCY_BUCKETS_MS,
    NULL_REGISTRY,
    MetricsRegistry,
    NullRegistry,
    use_metrics,
    validate_metrics_snapshot,
)
from .metrics import current as metrics_current
from .profiler import ProfileReport, profile_artifact
from .provenance import git_sha, provenance
from .snapshot import diff_is_empty, diff_snapshots, format_dfg, snapshot_dfg
from .tracer import (
    CATEGORIES,
    NULL_TRACER,
    NullTracer,
    Tracer,
    counter,
    current,
    instant,
    span,
    tracing_active,
    use_tracer,
    validate_chrome_trace,
)

__all__ = [
    "CATEGORIES",
    "LATENCY_BUCKETS_MS",
    "NULL_REGISTRY",
    "NULL_TRACER",
    "MetricsRegistry",
    "NullRegistry",
    "NullTracer",
    "ProfileReport",
    "Tracer",
    "counter",
    "current",
    "diff_is_empty",
    "diff_snapshots",
    "format_dfg",
    "git_sha",
    "instant",
    "metrics_current",
    "profile_artifact",
    "provenance",
    "snapshot_dfg",
    "span",
    "tracing_active",
    "use_metrics",
    "use_tracer",
    "validate_chrome_trace",
]
