"""Compiler & runtime instrumentation (ISSUE 6) — zero-dependency.

One layer, three pieces:

* :mod:`repro.instrument.tracer` — the span/instant/counter
  :class:`Tracer`, the ambient contextvar slot (:func:`use_tracer` /
  :func:`current`), and Chrome trace-event export + validation;
* :mod:`repro.instrument.snapshot` — structural DFG snapshots and
  diffs (``-print-ir-after-all``);
* :mod:`repro.instrument.provenance` — git-sha/host/time stamps for
  BENCH rows and exported traces.

The contract that makes this safe to thread everywhere: with no tracer
installed, every entry point here is a true no-op and instrumented code
produces byte-identical output (pinned by ``tests/test_instrument.py``).
"""
from .provenance import git_sha, provenance
from .snapshot import diff_is_empty, diff_snapshots, format_dfg, snapshot_dfg
from .tracer import (
    CATEGORIES,
    NULL_TRACER,
    NullTracer,
    Tracer,
    counter,
    current,
    instant,
    span,
    tracing_active,
    use_tracer,
    validate_chrome_trace,
)

__all__ = [
    "CATEGORIES",
    "NULL_TRACER",
    "NullTracer",
    "Tracer",
    "counter",
    "current",
    "diff_is_empty",
    "diff_snapshots",
    "format_dfg",
    "git_sha",
    "instant",
    "provenance",
    "snapshot_dfg",
    "span",
    "tracing_active",
    "use_tracer",
    "validate_chrome_trace",
]
