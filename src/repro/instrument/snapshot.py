"""Structural DFG snapshots + diffs — our ``-print-ir-after-all``.

A snapshot is a plain-dict summary of a DFG's structure: nodes (payload,
operands, epilogue, dims) and values (shape, bits, constness).  It is
deliberately *structural*, not textual: two snapshots diff in O(nodes)
and the diff names exactly what a pass did — nodes added/removed/rewritten,
values added/removed — which is what you want attached to a per-pass
trace event (the full textual IR is available via :func:`format_dfg`
when a tracer asks for ``ir_snapshots``).
"""
from __future__ import annotations

from typing import Mapping


def snapshot_dfg(dfg) -> dict:
    """Structural summary of a :class:`repro.core.ir.DFG` (plain data,
    JSON-serializable, cheap to diff)."""
    return {
        "name": dfg.name,
        "inputs": list(dfg.graph_inputs),
        "outputs": list(dfg.graph_outputs),
        "nodes": {
            op.name: {
                "payload": op.payload.value,
                "inputs": list(op.inputs),
                "output": op.output,
                "dims": list(op.dim_sizes),
                "epilogue": [e.kind.value for e in op.epilogue],
            }
            for op in dfg.nodes
        },
        "values": {
            name: {
                "shape": list(v.shape),
                "bits": v.elem_bits,
                "const": bool(v.is_constant),
            }
            for name, v in dfg.values.items()
        },
    }


def diff_snapshots(before: Mapping, after: Mapping) -> dict:
    """What changed between two snapshots, by name.

    ``changed`` means a node kept its name but its structure (operands,
    payload, epilogue, dims) was rewritten — fusion folding an
    activation into a conv shows up here."""
    b_nodes, a_nodes = before["nodes"], after["nodes"]
    b_vals, a_vals = before["values"], after["values"]
    return {
        "nodes_added": sorted(set(a_nodes) - set(b_nodes)),
        "nodes_removed": sorted(set(b_nodes) - set(a_nodes)),
        "nodes_changed": sorted(
            n for n in set(a_nodes) & set(b_nodes)
            if a_nodes[n] != b_nodes[n]
        ),
        "values_added": sorted(set(a_vals) - set(b_vals)),
        "values_removed": sorted(set(b_vals) - set(a_vals)),
    }


def diff_is_empty(diff: Mapping) -> bool:
    return not any(diff.values())


def format_dfg(dfg) -> str:
    """Human-readable IR dump (one line per node, topological order) —
    the payload of an ``ir_after`` event when full snapshots are on."""
    lines = [f"dfg @{dfg.name} "
             f"inputs={list(dfg.graph_inputs)} "
             f"outputs={list(dfg.graph_outputs)}"]
    for op in dfg.topo_order():
        epi = "".join(
            f" +{e.kind.value}" for e in op.epilogue
        )
        shape = tuple(dfg.values[op.output].shape)
        lines.append(
            f"  {op.output}:{shape} = {op.payload.value}"
            f"({', '.join(op.inputs)}) dims={list(op.dim_sizes)}{epi}"
        )
    return "\n".join(lines)
