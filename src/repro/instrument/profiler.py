"""Modeled-vs-measured profiler: close the loop on the resource model.

The compile pipeline *predicts* — per-group pipeline cycles out of the
DSE's analytical estimate (paper Sec. IV-C) — and the runtime
*measures* — per-group wall times from :func:`repro.kernels.ops.
run_compiled` (collected via ``stats_out``, blocking on each group).
Nothing reconciled the two: the carried-over "ZU3EG datasheet numbers
need calibration" roadmap item is exactly the question "which groups
does the model get wrong, and by how much?".

:func:`profile_artifact` runs a compiled artifact ``reps`` times
(after ``warmup`` discarded runs so jit compilation never pollutes the
measurement), takes the **min** wall per group (min, not mean: wall
noise on a shared host is one-sided), and joins against the model:

* ``modeled_cycles`` — the group's DSE pipeline-cycle estimate;
* ``modeled_ms`` — those cycles at the nominal fabric clock
  (``clock_mhz``, default the 300 MHz the DRAM model assumes);
* ``implied_clock_mhz`` — the clock at which the modeled cycles would
  explain the measured wall (modeled_cycles / measured_wall);
* ``ratio`` — measured_ms / modeled_ms, the model-error ratio;
* ``roofline_util`` — modeled cycles vs. the compute/bandwidth
  roofline bound (via :mod:`benchmarks.roofline` when importable —
  the benchmarks package lives at the repo root, so installed-package
  use degrades to ``None`` rather than failing);
* per-layer attribution: each group's measured wall split across its
  :class:`~repro.core.resource_model.NodeEstimate` rows by modeled
  cycle share.

Absolute ratios are only meaningful on a real fabric; on the CPU
interpret path every group shares the same (huge, meaningless)
scaling.  Drift detection therefore flags groups whose ratio deviates
from the **median group ratio** by more than ``threshold``× in either
direction — the shape of the error profile transfers even when its
scale does not.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


def _median(xs: list) -> float:
    s = sorted(xs)
    n = len(s)
    if not n:
        return 0.0
    mid = n // 2
    return s[mid] if n % 2 else (s[mid - 1] + s[mid]) / 2.0


def _roofline_util(macs: int, dma_bytes: int, cycles: int,
                   d_total: int, elem_bits: int = 8) -> Optional[float]:
    """Roofline utilization of one group: the ideal cycle count under
    the compute/bandwidth roofline divided by the modeled cycles.
    Delegates to :func:`benchmarks.roofline.edge_ideal_cycles` when the
    repo-root ``benchmarks`` package is importable; ``None`` otherwise."""
    try:
        from benchmarks.roofline import edge_ideal_cycles
    except ImportError:
        return None
    ideal = edge_ideal_cycles(macs, dma_bytes, d_total=d_total,
                              elem_bits=elem_bits)
    if cycles <= 0:
        return None
    return min(1.0, ideal / cycles) if ideal else 0.0


@dataclasses.dataclass
class ProfileReport:
    """The modeled-vs-measured join for one compiled artifact.

    ``groups``/``layers`` are lists of plain dicts (JSON-ready);
    ``flagged`` names the groups whose model-error ratio drifted past
    ``threshold``× the median."""

    model: str
    target: Optional[str]
    clock_mhz: float
    threshold: float
    reps: int
    interpret: bool
    groups: list
    layers: list
    flagged: list
    total_modeled_cycles: int
    total_measured_ms: float

    def to_json(self) -> dict:
        return {
            "version": 1,
            "model": self.model,
            "target": self.target,
            "clock_mhz": self.clock_mhz,
            "threshold": self.threshold,
            "reps": self.reps,
            "interpret": self.interpret,
            "total_modeled_cycles": self.total_modeled_cycles,
            "total_measured_ms": round(self.total_measured_ms, 4),
            "flagged": list(self.flagged),
            "groups": self.groups,
            "layers": self.layers,
        }

    def format_table(self, *, layers: bool = True) -> str:
        """The human-facing per-group (and optional per-layer) table."""
        lines = [
            f"profile: {self.model}"
            + (f" @ {self.target}" if self.target else "")
            + f"  (clock {self.clock_mhz:g} MHz, {self.reps} reps, "
            + ("interpret)" if self.interpret else "device)"),
            "",
            f"{'group':<14} {'modeled_cyc':>12} {'modeled_ms':>11} "
            f"{'measured_ms':>12} {'impl_MHz':>9} {'ratio':>8} "
            f"{'roofline':>9}  flag",
        ]
        for g in self.groups:
            roof = (f"{g['roofline_util']:.2f}"
                    if g.get("roofline_util") is not None else "-")
            lines.append(
                f"{g['group']:<14} {g['modeled_cycles']:>12,} "
                f"{g['modeled_ms']:>11.4f} {g['measured_ms']:>12.4f} "
                f"{g['implied_clock_mhz']:>9.2f} {g['ratio']:>8.2f} "
                f"{roof:>9}  {'DRIFT' if g['drift'] else ''}"
            )
        t_ms = self.total_modeled_cycles / (self.clock_mhz * 1e3)
        lines.append(
            f"{'total':<14} {self.total_modeled_cycles:>12,} "
            f"{t_ms:>11.4f} {self.total_measured_ms:>12.4f}"
        )
        if self.flagged:
            lines.append("")
            lines.append(
                f"drift (> {self.threshold:g}x off the median ratio): "
                + ", ".join(self.flagged)
            )
        if layers and self.layers:
            lines.append("")
            lines.append(
                f"{'layer':<22} {'group':<12} {'modeled_cyc':>12} "
                f"{'share':>6} {'attr_ms':>9} {'macs':>12} {'dsp':>6} "
                f"{'bram':>5}"
            )
            for n in self.layers:
                lines.append(
                    f"{n['name']:<22} {n['group']:<12} "
                    f"{n['modeled_cycles']:>12,} {n['share']:>6.2f} "
                    f"{n['attributed_ms']:>9.4f} {n['macs']:>12,} "
                    f"{n['dsp']:>6} {n['bram']:>5}"
                )
        return "\n".join(lines)


def profile_artifact(artifact, *, reps: int = 3, warmup: int = 1,
                     clock_mhz: float = 300.0, threshold: float = 2.0,
                     seed: int = 0,
                     interpret: Optional[bool] = None) -> ProfileReport:
    """Profile one :class:`~repro.api.artifact.CompiledArtifact`:
    execute it ``warmup + reps`` times on seeded random inputs and join
    per-group measured walls against the resource model (module
    docstring has the column definitions)."""
    if reps < 1:
        raise ValueError(f"reps must be >= 1, got {reps}")
    if threshold <= 1.0:
        raise ValueError(f"threshold must be > 1, got {threshold}")
    if clock_mhz <= 0:
        raise ValueError(f"clock_mhz must be > 0, got {clock_mhz}")
    design = artifact.design
    src = design.source

    walls: dict[str, list] = {g.name: [] for g in design.groups}
    for i in range(warmup + reps):
        artifact.run(seed=seed, interpret=interpret)
        if i < warmup:
            continue
        stats = artifact.last_run_stats or {}
        for row in stats.get("groups", ()):
            if row.get("wall_ms") is not None:
                walls[row["group"]].append(row["wall_ms"])

    transitions = design.boundary_traffic()
    rows = []
    for idx, g in enumerate(design.groups):
        measured = min(walls[g.name]) if walls[g.name] else 0.0
        modeled_cycles = g.cycles
        modeled_ms = modeled_cycles / (clock_mhz * 1e3)
        w, r = transitions[idx] if idx < len(transitions) else (0, 0)
        measured_s = measured / 1e3
        implied = (modeled_cycles / measured_s / 1e6) if measured_s > 0 \
            else 0.0
        ratio = (measured / modeled_ms) if modeled_ms > 0 else 0.0
        rows.append({
            "group": g.name,
            "nodes": len(g.dfg.nodes),
            "modeled_cycles": modeled_cycles,
            "modeled_ms": round(modeled_ms, 6),
            "measured_ms": round(measured, 4),
            "implied_clock_mhz": round(implied, 3),
            "ratio": round(ratio, 4),
            "dma_write_bytes": w,
            "dma_read_bytes": r,
            "macs": g.dse.estimate.macs,
            "dsp": g.dsp,
            "bram": g.bram,
            "roofline_util": _roofline_util(
                g.dse.estimate.macs, w + r, modeled_cycles, design.d_total
            ),
            "drift": False,
        })

    # drift: ratio vs the median group ratio (scale-free, so the CPU
    # interpret path still produces a meaningful error *profile*)
    ratios = [row["ratio"] for row in rows if row["ratio"] > 0]
    med = _median(ratios)
    flagged = []
    if med > 0 and len(rows) > 1:
        for row in rows:
            if row["ratio"] <= 0:
                continue
            if row["ratio"] > med * threshold or \
                    row["ratio"] < med / threshold:
                row["drift"] = True
                flagged.append(row["group"])

    layers = []
    for g, grow in zip(design.groups, rows):
        nodes = g.dse.estimate.nodes
        total = sum(n.cycles for n in nodes) or 1
        for n in nodes:
            share = n.cycles / total
            layers.append({
                "name": n.name,
                "group": g.name,
                "modeled_cycles": n.cycles,
                "share": round(share, 4),
                "attributed_ms": round(grow["measured_ms"] * share, 4),
                "macs": n.macs,
                "dsp": n.dsp,
                "bram": n.bram,
                "fill": n.fill,
            })

    from repro.kernels.ops import _auto_interpret  # lazy: avoids a cycle

    return ProfileReport(
        model=src.name,
        target=getattr(design.target, "name", None),
        clock_mhz=clock_mhz,
        threshold=threshold,
        reps=reps,
        interpret=bool(_auto_interpret(interpret)),
        groups=rows,
        layers=layers,
        flagged=flagged,
        total_modeled_cycles=design.total_cycles,
        total_measured_ms=round(sum(r["measured_ms"] for r in rows), 4),
    )
