"""Distribution: activation sharding context, parameter sharding rules."""
