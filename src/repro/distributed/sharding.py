"""Parameter / batch / cache sharding rules (GSPMD partition specs).

Axis roles:
  ``model``          — tensor parallelism (heads, d_ff, vocab, experts)
  ``data`` (+``pod``) — batch parallelism; together they form the FSDP
                        axis group along which params & optimizer states
                        are fully sharded.

Rules are keyed on leaf *names* (the pytree key path suffix), with one
structural convention: leaves under a ``blocks`` subtree carry a leading
layer-stack axis (from scan-over-layers) which is never sharded.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def fsdp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def dp_axes(mesh: Mesh):
    return fsdp_axes(mesh)


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------


#: attention leaves whose TP sharding slices q-heads / kv-heads
_Q_HEAD_LEAVES = frozenset({"wq", "wo", "bq"})
_KV_HEAD_LEAVES = frozenset({"wk", "wv", "bk", "bv"})


def _param_spec_for(name: str, ndim: int, fsdp, *, q_ok=True, kv_ok=True) -> P:
    """Spec for an *unstacked* leaf (stack prefix handled by caller).

    ``q_ok`` / ``kv_ok``: whether TP may shard the q / kv head axes.
    When heads don't divide the model axis, GSPMD would slice *inside*
    head_dim and insert an all-reduce of every (bq, bk) score block — the
    dominant collective in the unaware baseline (EXPERIMENTS.md §Perf
    iteration A1: 28.9 s of a 31.2 s collective term on qwen2-0.5b) — so
    these leaves replicate their head axis instead.
    """
    if name in ("embed",):                       # (V, D): vocab-parallel
        return P("model", fsdp)
    if name in ("lm_head",):                     # (D, V)
        return P(fsdp, "model")
    if name in _Q_HEAD_LEAVES and not q_ok:
        if name == "wq":
            return P(fsdp, None)
        if name == "wo":
            return P(None, fsdp)
        return P(None)                           # bq
    if name in _KV_HEAD_LEAVES and not kv_ok:
        if name in ("wk", "wv"):
            return P(fsdp, None)
        return P(None)                           # bk / bv
    if name in ("wq", "wk", "wv", "wu", "wg", "in_proj"):   # (D, X)
        if ndim == 3:                            # MoE experts (E, D, F)
            return P("model", fsdp, None)
        return P(fsdp, "model")
    if name in ("wo", "wd", "out_proj"):         # (X, D)
        if ndim == 3:                            # MoE experts (E, F, D)
            return P("model", None, fsdp)
        return P("model", fsdp)
    if name == "router":                         # (D, E)
        return P(fsdp, None)
    if name == "conv_w":                         # (K, conv_dim)
        return P(None, "model")
    if name in ("bq", "bk", "bv"):               # (X,)
        return P("model")
    # norms / scalars / per-head vectors: replicate
    return P(*([None] * 0))


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    """True when a dim of this size can shard over the axis group."""
    n = axis_size(mesh, axes)
    return n > 0 and dim % n == 0


def make_param_shardings(mesh: Mesh, params_shape: Any, cfg=None) -> Any:
    """Pytree of NamedShardings congruent with the params pytree.

    Divisibility-aware: any proposed axis that does not evenly divide the
    corresponding dim is dropped (falls back to replication on that dim) —
    e.g. unpadded vocabs (50280, 49155, 256206) cannot vocab-shard over a
    model=16 axis; the §Perf vocab-padding optimization removes exactly
    this fallback.  ``cfg`` (a ModelConfig) enables head-aware attention
    sharding — see :func:`_param_spec_for`.
    """
    fsdp = fsdp_axes(mesh)
    tp = mesh.shape.get("model", 1)
    q_ok = cfg is None or cfg.num_heads == 0 or cfg.num_heads % tp == 0
    kv_ok = cfg is None or cfg.num_kv_heads == 0 or cfg.num_kv_heads % tp == 0

    def spec_for(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        name = keys[-1]
        ndim = len(leaf.shape)
        stacked = "blocks" in keys
        if stacked:
            base = _param_spec_for(name, ndim - 1, fsdp, q_ok=q_ok,
                                   kv_ok=kv_ok)
            parts = (None, *tuple(base))
        else:
            parts = tuple(
                _param_spec_for(name, ndim, fsdp, q_ok=q_ok, kv_ok=kv_ok)
            )
        # pad/validate rank
        if len(parts) > ndim:
            parts = parts[:ndim]
        parts = parts + (None,) * (ndim - len(parts))
        # drop axes that do not divide the dim
        parts = tuple(
            a if _fits(leaf.shape[i], mesh, a) else None
            for i, a in enumerate(parts)
        )
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def make_opt_shardings(mesh: Mesh, opt_state_shape: Any,
                       param_shardings: Any) -> Any:
    """Optimizer state: moments follow param sharding; scalars replicate."""
    repl = NamedSharding(mesh, P())
    flat_p = {
        tuple(_path_str(p)): s
        for p, s in jax.tree_util.tree_flatten_with_path(param_shardings)[0]
    }

    def spec_for(path, leaf):
        keys = _path_str(path)
        # AdamWState fields: step / mu / nu / nu_scale — mu/nu subtrees are
        # congruent with params, so match on the path suffix
        if len(leaf.shape) == 0:
            return repl
        for plen in range(len(keys)):
            cand = tuple(keys[plen:])
            if cand in flat_p:
                return flat_p[cand]
        return repl

    return jax.tree_util.tree_map_with_path(spec_for, opt_state_shape)


def _path_str(path) -> list[str]:
    out = []
    for k in path:
        out.append(str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k)))))
    return out


# ---------------------------------------------------------------------------
# batch / cache rules
# ---------------------------------------------------------------------------


def make_batch_shardings(mesh: Mesh, batch_shape: Any) -> Any:
    """Batch over the DP axis group — adaptively dropped when the batch
    dim does not divide it (long_500k's global_batch=1)."""
    dp = dp_axes(mesh)

    def spec_for(path, leaf):
        name = _path_str(path)[-1]
        nd = len(leaf.shape)
        if name == "mrope_positions":               # (3, B, S)
            d = dp if _fits(leaf.shape[1], mesh, dp) else None
            return NamedSharding(mesh, P(None, d, None))
        if name in ("tokens", "labels", "embeds", "frames", "token"):
            d = dp if _fits(leaf.shape[0], mesh, dp) else None
            return NamedSharding(mesh, P(d, *([None] * (nd - 1))))
        return NamedSharding(mesh, P(*([None] * nd)))

    return jax.tree_util.tree_map_with_path(spec_for, batch_shape)


def make_cache_shardings(mesh: Mesh, cache_shape: Any) -> Any:
    """KV / SSM cache sharding with divisibility-aware fallbacks.

    Preference order for attention KV (L, B, Hkv, S, hd):
      1. heads over ``model`` (no resharding inside attention),
      2. sequence over ``model`` when Hkv doesn't divide it (GQA archs
         with Hkv=8 on a model=16 mesh — the cache stays distributed and
         decode's cache-update touches one shard),
    batch over the DP group whenever divisible.
    """
    dp = dp_axes(mesh)

    def kv_spec(shape):
        _, b, h, s, _ = shape
        d = dp if _fits(b, mesh, dp) else None
        if _fits(h, mesh, "model"):
            return P(None, d, "model", None, None)
        if _fits(s, mesh, "model"):
            return P(None, d, None, "model", None)
        return P(None, d, None, None, None)

    def spec_for(path, leaf):
        name = _path_str(path)[-1]
        nd = len(leaf.shape)
        if name in ("k", "v", "ck", "cv") and nd == 5:
            return NamedSharding(mesh, kv_spec(leaf.shape))
        if name == "conv" and nd == 4:          # (L, B, K-1, conv_dim)
            d = dp if _fits(leaf.shape[1], mesh, dp) else None
            m = "model" if _fits(leaf.shape[3], mesh, "model") else None
            return NamedSharding(mesh, P(None, d, None, m))
        if name == "ssm" and nd == 5:           # (L, B, H, P, N)
            d = dp if _fits(leaf.shape[1], mesh, dp) else None
            m = "model" if _fits(leaf.shape[2], mesh, "model") else None
            return NamedSharding(mesh, P(None, d, m, None, None))
        return NamedSharding(mesh, P(*([None] * nd)))

    return jax.tree_util.tree_map_with_path(spec_for, cache_shape)


# ---------------------------------------------------------------------------
# activation hook (installed by launchers; models call shard_activation)
# ---------------------------------------------------------------------------


def activation_hook(mesh: Mesh) -> Callable:
    dp = dp_axes(mesh)

    def hook(x, kind: str):
        if kind == "hidden" and x.ndim == 3:        # (B, S, D)
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(dp, None, None))
            )
        if kind == "logits" and x.ndim == 3:        # (B, c, V)
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(dp, None, "model"))
            )
        return x

    return hook
