"""Activation-sharding context.

Model code stays mesh-agnostic: it calls ``shard_activation(x, kind)`` at
layer boundaries; the launcher installs a hook that applies
``with_sharding_constraint`` with the mesh's axis names.  On a single
device (smoke tests) the hook is identity.
"""
from __future__ import annotations

import contextlib
from typing import Callable, Optional

import jax

_HOOK: Optional[Callable[[jax.Array, str], jax.Array]] = None


def set_activation_sharding(hook: Optional[Callable]) -> None:
    global _HOOK
    _HOOK = hook


def shard_activation(x: jax.Array, kind: str) -> jax.Array:
    """kind ∈ {'hidden', 'tokens', 'logits', 'kv_cache', 'expert_buf'}."""
    if _HOOK is None:
        return x
    return _HOOK(x, kind)


@contextlib.contextmanager
def activation_sharding(hook: Optional[Callable]):
    global _HOOK
    prev = _HOOK
    _HOOK = hook
    try:
        yield
    finally:
        _HOOK = prev
