"""data substrate."""
