"""Deterministic sharded synthetic data pipeline.

Produces reproducible LM batches keyed by (seed, step) — every host can
independently generate exactly its shard (no data server needed), the
property large-scale runs rely on for restart determinism: resuming from
step N regenerates the same batch N+1 bit-for-bit (tested).

Token stream: a Zipf-ish unigram mix with induced bigram structure, so
losses are non-degenerate (the model can actually learn next-token
statistics in the example trainers).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab_size: int = 32_000
    seq_len: int = 1_024
    global_batch: int = 8
    # sharding: this host generates rows [host_row_start, host_row_end)
    host_row_start: int = 0
    host_row_end: Optional[int] = None


def _batch_tokens(cfg: DataConfig, step: int) -> np.ndarray:
    """Deterministic (rows, seq+1) token block for a step."""
    end = cfg.host_row_end if cfg.host_row_end is not None else cfg.global_batch
    rows = end - cfg.host_row_start
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_row_start])
    )
    v = cfg.vocab_size
    # zipf-ish unigram draw
    base = rng.zipf(1.3, size=(rows, cfg.seq_len + 1)).astype(np.int64)
    base = (base - 1) % v
    # induce bigram structure: with p=0.5, next token = f(prev)
    follow = (base[:, :-1] * 2654435761 % v).astype(np.int64)
    coin = rng.random((rows, cfg.seq_len)) < 0.5
    base[:, 1:] = np.where(coin, follow, base[:, 1:])
    return base.astype(np.int32)


def lm_batch(cfg: DataConfig, step: int) -> dict:
    """{"tokens": (rows, S), "labels": (rows, S)} — next-token shifted."""
    block = _batch_tokens(cfg, step)
    return {"tokens": block[:, :-1], "labels": block[:, 1:]}


class LmDataIterator:
    """Stateful iterator with an explicit, checkpointable cursor."""

    def __init__(self, cfg: DataConfig, start_step: int = 0) -> None:
        self.cfg = cfg
        self.step = start_step

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        b = lm_batch(self.cfg, self.step)
        self.step += 1
        return b

    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])


def batch_for_model(cfg: ModelConfig, shape: ShapeConfig,
                    data: DataConfig, step: int) -> dict:
    """Model-family-aware batch (embeds for stub-frontend archs)."""
    b = lm_batch(dataclasses.replace(
        data, vocab_size=cfg.vocab_size, seq_len=shape.seq_len,
        global_batch=shape.global_batch), step)
    out: dict = {"labels": jnp.asarray(b["labels"])}
    if cfg.embeds_input:
        # stub frontend: hash tokens into embeddings deterministically
        rng = np.random.default_rng(np.random.SeedSequence([data.seed, 7, step]))
        emb = rng.normal(size=(*b["tokens"].shape, cfg.d_model)).astype(np.float32)
        out["embeds"] = jnp.asarray(emb).astype(cfg.param_dtype)
        if cfg.mrope_sections:
            s = b["tokens"].shape[1]
            pos = np.broadcast_to(
                np.arange(s, dtype=np.int32), (3, b["tokens"].shape[0], s)
            )
            out["mrope_positions"] = jnp.asarray(pos.copy())
    else:
        out["tokens"] = jnp.asarray(b["tokens"])
    return out
