"""The public API — one front door for the whole reproduction.

Three pieces (ISSUE 4):

* the **layer-builder frontend** (:mod:`repro.api.builder`):
  :class:`Sequential` / :class:`Graph` combinators with automatic shape
  inference and validating errors, replacing hand-assembled DFGs;
* :class:`CompileOptions` (re-exported from
  :mod:`repro.core.compile_driver`): every compile knob in one frozen,
  validated bundle;
* :class:`CompiledArtifact` (:mod:`repro.api.artifact`): the handle a
  compile returns — ``emit_hls`` / ``run`` / ``report`` / ``save`` /
  ``load``.

Typical session::

    from repro.api import Sequential, Conv2D, ReLU, MaxPool, \
        CompileOptions, compile_graph

    net = Sequential([Conv2D(16), ReLU(), MaxPool(2)],
                     input_shape=(1, 32, 32, 3), name="demo")
    art = compile_graph(net, CompileOptions(target="kv260"))
    print(art.report())
    art.emit_hls("out/")
    y = art.run(x)

Everything here is also re-exported at the package top level
(``import repro; repro.compile_graph(...)``), and drivable from the
shell via ``python -m repro compile <graph> --target kv260 --emit out/``.
"""
from repro.core.compile_driver import (
    KV260,
    TARGETS,
    ZU3EG,
    CompiledDesign,
    CompileOptions,
    Target,
    compile_design,
)

from repro.analyze import (
    Diagnostic,
    LintError,
    Severity,
    analyze_design,
    diagnostics_to_json,
)
from repro.instrument import Tracer, use_tracer, validate_chrome_trace

from .artifact import (
    CompiledArtifact,
    GroupReport,
    Report,
    TransitionReport,
    compile_graph,
)
from .builder import (
    Activation,
    AvgPool,
    Conv2D,
    Dense,
    Flatten,
    FrontendError,
    Graph,
    MaxPool,
    ReLU,
    Residual,
    Sequential,
    TensorRef,
    Transpose,
)


def suite() -> dict:
    """The named graphs the CLI / benchmarks can compile out of the box:
    the paper suite, the fusion and weight-streaming showcases, and the
    model zoo (``repro.frontends.zoo``) — every one built through the
    declarative frontend, every one a per-target row in
    ``BENCH_smoke.json``."""
    from repro.core import cnn_graphs
    from repro.frontends import zoo

    out = dict(cnn_graphs.PAPER_SUITE)
    out["conv_pool_32"] = lambda: cnn_graphs.conv_pool(32)
    out["conv_avgpool_32"] = lambda: cnn_graphs.conv_avgpool(32)
    out["fat_conv_16"] = cnn_graphs.fat_conv
    out["fat_cascade_16"] = cnn_graphs.fat_cascade
    out.update(zoo.ZOO)
    return out


__all__ = [
    "KV260",
    "TARGETS",
    "ZU3EG",
    "CompiledDesign",
    "CompileOptions",
    "Target",
    "compile_design",
    "CompiledArtifact",
    "Diagnostic",
    "GroupReport",
    "LintError",
    "Report",
    "Severity",
    "Tracer",
    "TransitionReport",
    "analyze_design",
    "compile_graph",
    "diagnostics_to_json",
    "use_tracer",
    "validate_chrome_trace",
    "Activation",
    "AvgPool",
    "Conv2D",
    "Dense",
    "Flatten",
    "FrontendError",
    "Graph",
    "MaxPool",
    "ReLU",
    "Residual",
    "Sequential",
    "TensorRef",
    "Transpose",
    "suite",
]
