"""Declarative layer-builder frontend: the one place CNN graphs are made.

Before this module every graph in the suite was hand-assembled
value-by-value (``Value`` + ``make_conv2d_op`` + manual shape
bookkeeping).  The builder replaces that with two combinator levels:

* :class:`Graph` — an imperative builder with one method per layer kind
  (``conv2d`` / ``relu`` / ``max_pool`` / ``avg_pool`` / ``dense`` /
  ``add`` / …).  Every method infers the output shape from its inputs,
  validates ranks/extents/channel counts, and registers the values and
  the :class:`~repro.core.ir.GenericOp` in the underlying DFG.  Errors
  are :class:`FrontendError`\\ s that name the layer and say exactly
  which shape constraint broke.

* :class:`Sequential` — a declarative layer list (:class:`Conv2D`,
  :class:`ReLU`, :class:`MaxPool`, :class:`AvgPool`, :class:`Dense`,
  :class:`Residual`, …) compiled through a :class:`Graph`.  ``Residual``
  runs its body layers and adds the skip back in (the diamond the
  FIFO-depth sizing of Sec. IV-C exists for).

Naming is deterministic and matches the historical ``cnn_graphs``
convention (``conv{i}``/``w{i}``/``conv{i}_out``…) so the legacy
constructors are now thin wrappers over this module and the two
spellings produce *node-for-node identical* DFGs
(``tests/test_frontend.py`` pins that).  Every layer accepts
``name=``/``out=``/``weight=`` overrides for graphs whose historical
names predate the scheme (``feed_forward``'s ``h``/``y``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Union

from repro.core.ir import (
    DFG,
    PayloadKind,
    Value,
    make_broadcast_binary_op,
    make_conv2d_op,
    make_elementwise_op,
    make_flatten_op,
    make_matmul_op,
    make_pool2d_op,
    make_transpose_op,
)


class FrontendError(ValueError):
    """A layer's shape/validity constraint failed at build time."""


@dataclass(frozen=True)
class TensorRef:
    """A symbolic tensor flowing through the builder (name + shape)."""

    name: str
    shape: tuple[int, ...]
    elem_bits: int = 8

    @property
    def rank(self) -> int:
        return len(self.shape)


def _fail(layer: str, msg: str) -> None:
    raise FrontendError(f"{layer}: {msg}")


class Graph:
    """Imperative graph builder over the GenericOp DFG.

    >>> g = Graph("net")
    >>> x = g.input((1, 32, 32, 3))
    >>> y = g.relu(g.conv2d(x, 16))
    >>> g.output(y)
    >>> dfg = g.build()
    """

    def __init__(self, name: str, *, elem_bits: int = 8) -> None:
        self.dfg = DFG(name)
        self.elem_bits = elem_bits
        self._counters: dict[str, int] = {}
        self._n_weights = 0

    # -- naming --------------------------------------------------------------

    def _next(self, kind: str, name: Optional[str]) -> str:
        """Per-kind node counter (``conv0``, ``relu1``, …); explicit
        names still advance the counter so later layers stay aligned
        with the legacy numbering."""
        i = self._counters.get(kind, 0)
        self._counters[kind] = i + 1
        return name if name is not None else f"{kind}{i}"

    def _next_weight(self, name: Optional[str]) -> str:
        i = self._n_weights
        self._n_weights += 1
        return name if name is not None else f"w{i}"

    def _ref(self, value_name: str) -> TensorRef:
        v = self.dfg.values[value_name]
        return TensorRef(v.name, v.shape, v.elem_bits)

    def _check(self, layer: str, x) -> TensorRef:
        if not isinstance(x, TensorRef):
            _fail(layer, f"expected a TensorRef input, got {type(x).__name__}")
        if x.name not in self.dfg.values:
            _fail(layer, f"input {x.name!r} is not a value of graph "
                         f"{self.dfg.name!r}")
        return x

    # -- graph boundary ------------------------------------------------------

    def input(self, shape: Sequence[int], name: str = "x",
              elem_bits: Optional[int] = None) -> TensorRef:
        if not shape or any(int(s) <= 0 for s in shape):
            _fail(f"input {name!r}", f"shape {tuple(shape)} must be "
                                     "non-empty with positive extents")
        bits = elem_bits if elem_bits is not None else self.elem_bits
        self.dfg.add_value(Value(name, tuple(int(s) for s in shape), bits))
        self.dfg.graph_inputs.append(name)
        return self._ref(name)

    def constant(self, shape: Sequence[int], name: Optional[str] = None,
                 elem_bits: Optional[int] = None) -> TensorRef:
        """An on-chip constant (weights/bias) — never streamed."""
        bits = elem_bits if elem_bits is not None else self.elem_bits
        vname = self._next_weight(name)
        self.dfg.add_value(
            Value(vname, tuple(int(s) for s in shape), bits, is_constant=True)
        )
        return self._ref(vname)

    def output(self, x: TensorRef) -> TensorRef:
        self._check("output", x)
        if x.name not in self.dfg.graph_outputs:
            self.dfg.graph_outputs.append(x.name)
        return x

    # -- layers --------------------------------------------------------------

    def conv2d(self, x: TensorRef, filters: int, kernel: int = 3,
               stride: int = 1, *, padding: str = "SAME",
               name: Optional[str] = None,
               weight: Optional[str] = None,
               out: Optional[str] = None) -> TensorRef:
        """NHWC conv2d.  ``padding="SAME"`` (output spatial extent
        ``ceil(h/s)``, deficit zero-padded end-heavy — ONNX SAME_UPPER)
        or ``"VALID"`` (no padding, ``(h - k)//s + 1``)."""
        nm = self._next("conv", name)
        self._check(nm, x)
        if x.rank != 4:
            _fail(nm, f"conv2d needs a rank-4 NHWC input, got rank "
                      f"{x.rank} (shape {x.shape})")
        if filters < 1 or kernel < 1 or stride < 1:
            _fail(nm, f"filters/kernel/stride must be >= 1, got "
                      f"({filters}, {kernel}, {stride})")
        if padding not in ("SAME", "VALID"):
            _fail(nm, f'padding must be "SAME" or "VALID", got {padding!r}')
        n, h, w, c_in = x.shape
        if padding == "VALID":
            if kernel > h or kernel > w:
                _fail(nm, f"VALID conv kernel {kernel} exceeds the spatial "
                          f"extents {h}x{w}")
            h_out = (h - kernel) // stride + 1
            w_out = (w - kernel) // stride + 1
        else:
            h_out = -(-h // stride)
            w_out = -(-w // stride)
        wref = self.constant((kernel, kernel, c_in, filters), weight,
                             elem_bits=x.elem_bits)
        oname = out if out is not None else f"{nm}_out"
        self.dfg.add_value(
            Value(oname, (n, h_out, w_out, filters), x.elem_bits)
        )
        self.dfg.add_node(
            make_conv2d_op(
                nm, x.name, wref.name, oname,
                n=n, h_out=h_out, w_out=w_out, c_out=filters,
                kh=kernel, kw=kernel, c_in=c_in, stride=stride,
                elem_bits=x.elem_bits,
            )
        )
        return self._ref(oname)

    def activation(self, x: TensorRef, kind: PayloadKind, prefix: str, *,
                   name: Optional[str] = None,
                   out: Optional[str] = None) -> TensorRef:
        nm = self._next(prefix, name)
        self._check(nm, x)
        oname = out if out is not None else f"{nm}_out"
        self.dfg.add_value(Value(oname, x.shape, x.elem_bits))
        self.dfg.add_node(
            make_elementwise_op(nm, [x.name], oname, x.shape, kind,
                                elem_bits=x.elem_bits)
        )
        return self._ref(oname)

    def relu(self, x: TensorRef, *, name: Optional[str] = None,
             out: Optional[str] = None) -> TensorRef:
        return self.activation(x, PayloadKind.RELU, "relu", name=name, out=out)

    def _pool(self, x: TensorRef, window: int, stride: Optional[int],
              payload: PayloadKind, *, name: Optional[str],
              out: Optional[str]) -> TensorRef:
        nm = self._next("pool", name)
        self._check(nm, x)
        if x.rank != 4:
            _fail(nm, f"pool needs a rank-4 NHWC input, got rank {x.rank} "
                      f"(shape {x.shape})")
        stride = window if stride is None else stride
        n, h, w, c = x.shape
        if window < 1 or stride < 1:
            _fail(nm, f"window/stride must be >= 1, got ({window}, {stride})")
        if window > h or window > w:
            _fail(nm, f"pool window {window} exceeds the spatial extents "
                      f"{h}x{w}")
        if (h - window) % stride or (w - window) % stride:
            _fail(nm, f"illegal pool window: {window}x{window}/stride "
                      f"{stride} does not tile the {h}x{w} input exactly "
                      "(VALID pooling needs (extent - window) % stride == 0)")
        h_out = (h - window) // stride + 1
        w_out = (w - window) // stride + 1
        oname = out if out is not None else f"{nm}_out"
        self.dfg.add_value(Value(oname, (n, h_out, w_out, c), x.elem_bits))
        self.dfg.add_node(
            make_pool2d_op(
                nm, x.name, oname,
                n=n, h_out=h_out, w_out=w_out, c=c, kh=window, kw=window,
                stride=stride, payload=payload, elem_bits=x.elem_bits,
            )
        )
        return self._ref(oname)

    def max_pool(self, x: TensorRef, window: int = 2,
                 stride: Optional[int] = None, *,
                 name: Optional[str] = None,
                 out: Optional[str] = None) -> TensorRef:
        return self._pool(x, window, stride, PayloadKind.MAX,
                          name=name, out=out)

    def avg_pool(self, x: TensorRef, window: int = 2,
                 stride: Optional[int] = None, *,
                 name: Optional[str] = None,
                 out: Optional[str] = None) -> TensorRef:
        """Average pool — ADD accumulation plus the DIV exit path (see
        ``repro.kernels.ref.pool_reduce``)."""
        return self._pool(x, window, stride, PayloadKind.AVG,
                          name=name, out=out)

    def transpose(self, x: TensorRef, perm: Sequence[int], *,
                  name: Optional[str] = None,
                  out: Optional[str] = None) -> TensorRef:
        """Axis permutation (the NCHW↔NHWC bridge the ONNX importer
        inserts; ``repro.passes.layout`` cancels interior pairs)."""
        nm = self._next("transpose", name)
        self._check(nm, x)
        p = tuple(int(i) for i in perm)
        if sorted(p) != list(range(x.rank)):
            _fail(nm, f"perm {p} is not a permutation of the input's "
                      f"{x.rank} axes (shape {x.shape})")
        oname = out if out is not None else f"{nm}_out"
        self.dfg.add_value(
            Value(oname, tuple(x.shape[i] for i in p), x.elem_bits)
        )
        self.dfg.add_node(
            make_transpose_op(nm, x.name, oname, in_shape=x.shape, perm=p,
                              elem_bits=x.elem_bits)
        )
        return self._ref(oname)

    def flatten(self, x: TensorRef, *, order: Optional[Sequence[int]] = None,
                name: Optional[str] = None,
                out: Optional[str] = None) -> TensorRef:
        """Collapse every non-batch axis into one feature axis.

        ``order`` linearizes the non-batch axes in that sequence
        (default ascending — row-major over the producer's layout);
        the classifier heads of imported models flatten through this
        before their first ``dense``."""
        nm = self._next("flatten", name)
        self._check(nm, x)
        if x.rank < 2:
            _fail(nm, f"flatten needs a rank >= 2 input, got rank {x.rank} "
                      f"(shape {x.shape})")
        o = tuple(int(i) for i in order) if order is not None else None
        if o is not None and sorted(o) != list(range(1, x.rank)):
            _fail(nm, f"order {o} is not a permutation of the non-batch "
                      f"axes 1..{x.rank - 1}")
        feat = 1
        for s in x.shape[1:]:
            feat *= s
        oname = out if out is not None else f"{nm}_out"
        self.dfg.add_value(Value(oname, (x.shape[0], feat), x.elem_bits))
        self.dfg.add_node(
            make_flatten_op(nm, x.name, oname, in_shape=x.shape, order=o,
                            elem_bits=x.elem_bits)
        )
        return self._ref(oname)

    def dense(self, x: TensorRef, units: int, *,
              name: Optional[str] = None, weight: Optional[str] = None,
              out: Optional[str] = None) -> TensorRef:
        nm = self._next("linear", name)
        self._check(nm, x)
        if x.rank != 2:
            _fail(nm, f"dense needs a rank-2 (batch, features) input, got "
                      f"rank {x.rank} (shape {x.shape})")
        if units < 1:
            _fail(nm, f"units must be >= 1, got {units}")
        batch, d_in = x.shape
        wref = self.constant((d_in, units), weight, elem_bits=x.elem_bits)
        oname = out if out is not None else f"{nm}_out"
        self.dfg.add_value(Value(oname, (batch, units), x.elem_bits))
        self.dfg.add_node(
            make_matmul_op(nm, x.name, wref.name, oname,
                           m=batch, k=d_in, n_out=units,
                           elem_bits=x.elem_bits)
        )
        return self._ref(oname)

    def add(self, a: TensorRef, b: TensorRef, *,
            name: Optional[str] = None,
            out: Optional[str] = None) -> TensorRef:
        nm = self._next("add", name)
        self._check(nm, a)
        self._check(nm, b)
        oname = out if out is not None else f"{nm}_out"
        if a.shape != b.shape:
            # per-channel bias: a rank-1 *constant* matching the last
            # axis broadcasts through the indexing maps (C elements of
            # const buffer, not H*W*C)
            if (
                b.rank == 1
                and b.shape[0] == a.shape[-1]
                and self.dfg.values[b.name].is_constant
            ):
                self.dfg.add_value(Value(oname, a.shape, a.elem_bits))
                self.dfg.add_node(
                    make_broadcast_binary_op(
                        nm, a.name, b.name, oname, a.shape,
                        PayloadKind.ADD, elem_bits=a.elem_bits,
                    )
                )
                return self._ref(oname)
            _fail(nm, f"operand shapes differ: {a.shape} vs {b.shape} "
                      "(residual adds need identical shapes — check the "
                      "body's channel count and pooling; a per-channel "
                      "bias must be a rank-1 constant matching the last "
                      "axis)")
        self.dfg.add_value(Value(oname, a.shape, a.elem_bits))
        self.dfg.add_node(
            make_elementwise_op(nm, [a.name, b.name], oname, a.shape,
                                PayloadKind.ADD, elem_bits=a.elem_bits)
        )
        return self._ref(oname)

    # -- finalize ------------------------------------------------------------

    def build(self) -> DFG:
        if not self.dfg.graph_outputs:
            _fail(self.dfg.name, "graph has no outputs — call output(...)")
        return self.dfg


# ---------------------------------------------------------------------------
# Declarative layer specs (the Sequential combinator level)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Conv2D:
    filters: int
    kernel: int = 3
    stride: int = 1
    padding: str = "SAME"
    name: Optional[str] = None
    weight: Optional[str] = None
    out: Optional[str] = None

    def apply(self, g: Graph, x: TensorRef) -> TensorRef:
        return g.conv2d(x, self.filters, self.kernel, self.stride,
                        padding=self.padding, name=self.name,
                        weight=self.weight, out=self.out)


@dataclass(frozen=True)
class ReLU:
    name: Optional[str] = None
    out: Optional[str] = None

    def apply(self, g: Graph, x: TensorRef) -> TensorRef:
        return g.relu(x, name=self.name, out=self.out)


@dataclass(frozen=True)
class Activation:
    kind: PayloadKind
    name: Optional[str] = None
    out: Optional[str] = None

    def apply(self, g: Graph, x: TensorRef) -> TensorRef:
        prefix = self.kind.value
        return g.activation(x, self.kind, prefix, name=self.name,
                            out=self.out)


@dataclass(frozen=True)
class MaxPool:
    window: int = 2
    stride: Optional[int] = None
    name: Optional[str] = None
    out: Optional[str] = None

    def apply(self, g: Graph, x: TensorRef) -> TensorRef:
        return g.max_pool(x, self.window, self.stride, name=self.name,
                          out=self.out)


@dataclass(frozen=True)
class AvgPool:
    window: int = 2
    stride: Optional[int] = None
    name: Optional[str] = None
    out: Optional[str] = None

    def apply(self, g: Graph, x: TensorRef) -> TensorRef:
        return g.avg_pool(x, self.window, self.stride, name=self.name,
                          out=self.out)


@dataclass(frozen=True)
class Dense:
    units: int
    name: Optional[str] = None
    weight: Optional[str] = None
    out: Optional[str] = None

    def apply(self, g: Graph, x: TensorRef) -> TensorRef:
        return g.dense(x, self.units, name=self.name, weight=self.weight,
                       out=self.out)


@dataclass(frozen=True)
class Transpose:
    perm: tuple
    name: Optional[str] = None
    out: Optional[str] = None

    def __init__(self, perm: Sequence[int], name: Optional[str] = None,
                 out: Optional[str] = None) -> None:
        object.__setattr__(self, "perm", tuple(perm))
        object.__setattr__(self, "name", name)
        object.__setattr__(self, "out", out)

    def apply(self, g: Graph, x: TensorRef) -> TensorRef:
        return g.transpose(x, self.perm, name=self.name, out=self.out)


@dataclass(frozen=True)
class Flatten:
    order: Optional[tuple] = None
    name: Optional[str] = None
    out: Optional[str] = None

    def __init__(self, order: Optional[Sequence[int]] = None,
                 name: Optional[str] = None,
                 out: Optional[str] = None) -> None:
        object.__setattr__(self, "order",
                           tuple(order) if order is not None else None)
        object.__setattr__(self, "name", name)
        object.__setattr__(self, "out", out)

    def apply(self, g: Graph, x: TensorRef) -> TensorRef:
        return g.flatten(x, order=self.order, name=self.name, out=self.out)


@dataclass(frozen=True)
class Residual:
    """``y = add(body(x), x)`` — the skip connection combinator."""

    body: tuple = ()
    name: Optional[str] = None
    out: Optional[str] = None

    def __init__(self, body: Sequence, name: Optional[str] = None,
                 out: Optional[str] = None) -> None:
        object.__setattr__(self, "body", tuple(body))
        object.__setattr__(self, "name", name)
        object.__setattr__(self, "out", out)

    def apply(self, g: Graph, x: TensorRef) -> TensorRef:
        if not self.body:
            raise FrontendError(
                f"{g.dfg.name}: Residual needs at least one body layer "
                "(an empty body would silently compute x + x)"
            )
        cur = x
        for layer in self.body:
            cur = _apply_layer(g, layer, cur)
        return g.add(cur, x, name=self.name, out=self.out)


Layer = Union[Conv2D, ReLU, Activation, MaxPool, AvgPool, Dense, Residual,
              Transpose, Flatten]


def _apply_layer(g: Graph, layer, x: TensorRef) -> TensorRef:
    apply = getattr(layer, "apply", None)
    if apply is None:
        raise FrontendError(
            f"{g.dfg.name}: {layer!r} is not a layer (needs an "
            "apply(graph, x) method)"
        )
    return apply(g, x)


class Sequential:
    """A declarative chain of layers over one graph input.

    >>> net = Sequential(
    ...     [Conv2D(16), ReLU(), MaxPool(2)],
    ...     input_shape=(1, 32, 32, 3), name="conv_pool_32",
    ... )
    >>> dfg = net.build()

    ``build()`` is deterministic and cheap; repeated calls return fresh,
    structurally identical DFGs.
    """

    def __init__(self, layers: Sequence, *, input_shape: Sequence[int],
                 name: str = "model", input_name: str = "x",
                 elem_bits: int = 8) -> None:
        if not layers:
            raise FrontendError(f"{name}: Sequential needs at least one layer")
        self.layers = tuple(layers)
        self.input_shape = tuple(int(s) for s in input_shape)
        self.name = name
        self.input_name = input_name
        self.elem_bits = elem_bits

    def build(self) -> DFG:
        g = Graph(self.name, elem_bits=self.elem_bits)
        cur = g.input(self.input_shape, name=self.input_name)
        for layer in self.layers:
            cur = _apply_layer(g, layer, cur)
        g.output(cur)
        return g.build()
