"""`CompiledArtifact`: the session handle a compile returns.

hls4ml's ``convert → compile → predict`` one-call surface is the
adoption bar (PAPERS.md); this module is our equivalent.  One call —
:func:`compile_graph` — takes anything graph-shaped (a built
:class:`~repro.core.ir.DFG`, a :class:`~repro.api.builder.Sequential`,
or an open :class:`~repro.api.builder.Graph`) plus one
:class:`~repro.core.compile_driver.CompileOptions`, and hands back a
:class:`CompiledArtifact` that can

* ``emit_hls(outdir)``   — write the Vitis C++ kernels + host schedule,
* ``run(x)``             — execute on the Pallas path (interpret mode
                           on CPU), bit-exact with the DFG interpreter,
* ``report()``           — the cycles/BRAM/DSP/spill table per group,
* ``save()`` / ``load()``— persist the compiled design (the benchmark
                           cache uses this to skip recompiles).

The artifact holds plain schedule-IR state only (no jitted functions,
no arrays), so ``save``/``load`` is a straight pickle and a loaded
artifact re-lowers through the same executable cache as a fresh one.
"""
from __future__ import annotations

import contextlib
import math
import os
import pickle
import time
from dataclasses import dataclass, field
from typing import Mapping, Optional

import repro.instrument as instrument
from repro.core.compile_driver import (
    CompiledDesign,
    CompileOptions,
    compile_design,
)
from repro.core.ir import DFG
from repro.core.resource_model import transition_cycles

#: bumped when the pickled payload's schema changes; load() rejects
#: mismatches loudly instead of failing deep inside the schedule IR
_SAVE_VERSION = 1


@dataclass(frozen=True)
class GroupReport:
    """One row of :meth:`CompiledArtifact.report`."""

    name: str
    nodes: tuple[str, ...]
    cycles: int
    bram: int
    dsp: int
    spill_in_bytes: int
    spill_out_bytes: int
    weight_streamed: tuple[tuple[str, int], ...]


@dataclass(frozen=True)
class TransitionReport:
    """One group→group boundary: the DMA the host schedule overlaps."""

    left: str
    right: str
    write_bytes: int
    read_bytes: int
    cycles: int


@dataclass(frozen=True)
class Report:
    """Whole-design accounting, printable as a table.

    ``transitions`` itemizes the boundary DMA of a partitioned design
    (per cut: spill-write/fill-read bytes and the overlapped cycle
    cost) — previously only the aggregate ``spill_cycles`` was visible.

    ``telemetry`` (ISSUE 6) carries measured, non-deterministic data —
    per-pass wall times, partition-DP search statistics, jit-cache
    counters, the artifact's last ``run()`` stats — and is excluded
    from equality: two compiles of the same graph produce equal
    Reports even though their wall times differ.
    """

    graph: str
    target: str
    feasible: bool
    groups: tuple[GroupReport, ...]
    total_cycles: int
    max_group_cycles: int
    spill_cycles: int
    max_bram: int
    b_total: int
    max_dsp: int
    d_total: int
    spill_bytes: int
    transitions: tuple[TransitionReport, ...] = ()
    telemetry: Optional[dict] = field(default=None, compare=False)

    def __str__(self) -> str:
        head = (
            f"{self.graph} @ {self.target}: "
            f"{self.total_cycles / 1e6:.3f} Mcycles total "
            f"({self.spill_cycles} boundary DMA), "
            f"peak BRAM {self.max_bram}/{self.b_total}, "
            f"peak DSP {self.max_dsp}/{self.d_total}, "
            f"{'feasible' if self.feasible else 'INFEASIBLE'}"
        )
        lines = [head, "group,nodes,cycles,bram,dsp,spill_in_B,spill_out_B,"
                       "weight_streamed"]
        trans = {t.left: t for t in self.transitions}
        for g in self.groups:
            ws = ";".join(f"{n}/{t}" for n, t in g.weight_streamed) or "-"
            lines.append(
                f"{g.name},{'+'.join(g.nodes)},{g.cycles},{g.bram},{g.dsp},"
                f"{g.spill_in_bytes},{g.spill_out_bytes},{ws}"
            )
            t = trans.get(g.name)
            if t is not None:
                lines.append(
                    f"  -- dma {t.left}->{t.right}: "
                    f"write {t.write_bytes} B, read {t.read_bytes} B, "
                    f"{t.cycles} cycles (overlapped)"
                )
        lines.extend(self._telemetry_lines())
        return "\n".join(lines)

    def _telemetry_lines(self) -> list[str]:
        tel = self.telemetry
        if not tel:
            return []
        lines = ["telemetry:"]
        passes = tel.get("passes")
        if passes:
            total = sum(p["wall_ms"] for p in passes)
            hot = ", ".join(
                f"{p['name']} {p['wall_ms']:.2f}ms"
                for p in sorted(passes, key=lambda p: -p["wall_ms"])[:4]
            )
            lines.append(f"  passes: {total:.2f} ms total ({hot})")
        dp = tel.get("partition")
        if dp:
            rej = dp.get("rejected_by_reason") or {}
            rej_s = " ".join(f"{k}:{v}" for k, v in sorted(rej.items()))
            lines.append(
                f"  partition: dp_states={dp.get('dp_states', 0)} "
                f"memo_hits={dp.get('dp_memo_hits', 0)} "
                f"ilp_solves={dp.get('ilp_solves', 0)} "
                f"streamed_resolves={dp.get('streamed_resolves', 0)} "
                f"rejected_cuts={len(dp.get('rejected_cuts', []))}"
                + (f" ({rej_s})" if rej_s else "")
            )
        cache = tel.get("exec_cache")
        if cache:
            lines.append(
                f"  jit cache: {cache.get('hits', 0)} hits / "
                f"{cache.get('misses', 0)} misses (cumulative)"
            )
        run = tel.get("last_run")
        if run:
            per_group = " ".join(
                f"{g['group']} {g['wall_ms']:.1f}ms({g['jit_cache']})"
                for g in run.get("groups", [])
            )
            lines.append(
                f"  last run: {run.get('samples', 1)} sample(s), "
                f"{run.get('wall_ms', 0.0):.1f} ms wall"
                + (f", groups: {per_group}" if per_group else "")
            )
        metrics = tel.get("metrics")
        if metrics:
            n_counters = len(metrics.get("counters", {}))
            n_gauges = len(metrics.get("gauges", {}))
            hists = metrics.get("histograms", {})
            obs = sum(
                row.get("count", 0)
                for h in hists.values() for row in h.get("values", [])
            )
            lines.append(
                f"  metrics: {n_counters} counter(s), {n_gauges} "
                f"gauge(s), {len(hists)} histogram(s) "
                f"({obs} observation(s))"
            )
        diag = tel.get("diagnostics")
        if diag:
            c = diag.get("counts", {})
            lines.append(
                f"  lint: {c.get('error', 0)} error(s), "
                f"{c.get('warning', 0)} warning(s), "
                f"{c.get('info', 0)} info"
            )
            for item in diag.get("items", []):
                if item.get("severity") in ("error", "warning"):
                    lines.append(
                        f"    {item['severity']}[{item['rule']}] "
                        f"{item.get('node') or item.get('group') or '-'}: "
                        f"{item['message']}"
                    )
        return lines


class CompiledArtifact:
    """A compiled design plus every way to consume it."""

    def __init__(self, design: CompiledDesign) -> None:
        self.design = design
        #: runtime counters of the most recent :meth:`run` (ISSUE 6):
        #: wall time, per-group latency + jit-cache outcome, exec-cache
        #: hit/miss delta, boundary-DMA bytes; ``None`` until a run
        self.last_run_stats: Optional[dict] = None

    @contextlib.contextmanager
    def _tracer_scope(self):
        """Install the compile-time tracer (``CompileOptions.trace``)
        for a consumer call, unless an enabled tracer is already
        ambient — runtime counters then land in the same trace as the
        compile spans.  Always yields a usable tracer (the no-op null
        tracer when nothing is attached)."""
        if instrument.current().enabled:
            yield instrument.current()
            return
        with instrument.use_tracer(self.design.tracer):
            yield instrument.current()

    @property
    def tracer(self):
        """The attached :class:`repro.instrument.Tracer` (or None)."""
        return self.design.tracer

    def write_trace(self, path: str, *,
                    provenance: Optional[Mapping] = None) -> str:
        """Export the attached tracer's events as Chrome trace-event
        JSON (validated before writing; load it in ``chrome://tracing``
        or Perfetto).  Requires a traced compile
        (``CompileOptions(trace=...)``)."""
        tracer = self.design.tracer
        if tracer is None:
            raise ValueError(
                "no trace attached — compile with "
                "CompileOptions(trace=True) (or --trace PATH on the CLI)"
            )
        extra = dict(provenance) if provenance else {}
        extra.setdefault("graph", self.source.name)
        extra.setdefault("target", self.target_name)
        return tracer.write(path,
                            provenance=instrument.provenance(extra))

    # -- identity ------------------------------------------------------------

    @property
    def source(self) -> DFG:
        """The (post-pass-pipeline) graph the groups partition."""
        return self.design.source

    @property
    def options(self) -> Optional[CompileOptions]:
        return self.design.options

    @property
    def target_name(self) -> str:
        return self.design.target.name if self.design.target else "custom"

    @property
    def feasible(self) -> bool:
        return self.design.feasible

    @property
    def diagnostics(self) -> list:
        """Static-analysis findings (``repro.analyze.Diagnostic``)
        collected at compile time under ``CompileOptions.lint``.
        ``getattr`` because pre-ISSUE 9 pickled designs lack the
        field."""
        return list(getattr(self.design, "diagnostics", None) or [])

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"<CompiledArtifact {self.source.name!r} @ {self.target_name} "
            f"groups={len(self.design.groups)} "
            f"cycles={self.design.total_cycles}>"
        )

    # -- backends ------------------------------------------------------------

    def emit_hls(self, outdir: str) -> list[str]:
        """Write one Vitis-style C++ kernel per group plus the host
        schedule into ``outdir``; returns the written paths."""
        from repro.core.emit_hls import emit_design

        os.makedirs(outdir, exist_ok=True)
        paths = []
        with self._tracer_scope():
            files = emit_design(self.design)
        for fname, contents in files.items():
            path = os.path.join(outdir, fname)
            with open(path, "w") as f:
                f.write(contents)
            paths.append(path)
        return paths

    def run(
        self,
        inputs=None,
        params: Optional[Mapping] = None,
        *,
        interpret: Optional[bool] = None,
        jit: bool = True,
        seed: int = 0,
        batch_mode: str = "vmap",
    ):
        """Execute the compiled schedule on the Pallas path.

        ``inputs`` is a ``{name: array}`` mapping, or a bare array when
        the graph has exactly one input.  Passing *some* inputs of a
        multi-input graph is an error; passing *none* runs a smoke
        execution on the deterministic small-integer initialization of
        ``repro.passes.interp.random_env(seed)`` (the CLI ``--run``
        path).  ``params`` binds constant values (weights/biases) —
        nothing else; unbound constants fall back to the same random
        init.  Returns the output array for single-output graphs, else
        ``{name: array}``.

        **Batching** (ISSUE 7): every input may carry one extra
        *leading* batch dimension over its compiled shape.  With the
        default ``batch_mode="vmap"`` the whole batch executes as one
        vmapped+jitted device dispatch per group
        (:func:`repro.kernels.ops.run_compiled_batched`): the batch is
        padded to a small set of bucket extents so recompiles stay
        bounded, outputs stay stacked on device and convert to NumPy
        once at the boundary.  ``batch_mode="loop"`` keeps the PR 5
        per-sample loop through the compiled schedule (the
        bit-exactness reference and the serving benchmark's baseline).
        Both modes produce bit-identical stacked outputs.  All inputs
        must agree on the batch extent; mixing batched and unbatched
        inputs is an error.
        """
        from repro.kernels import ops
        from repro.passes import interp

        if batch_mode not in ("vmap", "loop"):
            raise ValueError(
                f"batch_mode must be 'vmap' or 'loop', got {batch_mode!r}"
            )
        src = self.design.source
        if inputs is None:
            inputs = {}
        if not isinstance(inputs, Mapping):
            if len(src.graph_inputs) != 1:
                raise ValueError(
                    f"{src.name} has {len(src.graph_inputs)} inputs "
                    f"({src.graph_inputs}); pass a dict, not a bare array"
                )
            inputs = {src.graph_inputs[0]: inputs}
        for k in inputs:
            if k not in src.graph_inputs:
                raise KeyError(
                    f"{src.name}: {k!r} is not a graph input "
                    f"({src.graph_inputs})"
                )
        if inputs and set(inputs) != set(src.graph_inputs):
            # all-or-nothing: a partially bound multi-input graph would
            # silently run on random data for the forgotten input
            missing = sorted(set(src.graph_inputs) - set(inputs))
            raise ValueError(
                f"{src.name}: missing graph input(s) {missing} — bind "
                "every input, or none for a random smoke run"
            )
        constants = sorted(
            n for n, val in src.values.items() if val.is_constant
        )
        if params:
            for k in params:
                ok = k in src.graph_inputs or (
                    k in src.values and src.values[k].is_constant
                )
                if not ok:
                    raise KeyError(
                        f"{src.name}: param {k!r} is not a constant (or "
                        f"graph input) of the compiled graph — "
                        f"constants: {constants} (note: the pass "
                        "pipeline may have folded or renamed values of "
                        "the original graph)"
                    )
        batch = self._batch_extent(src, inputs)
        if batch is not None and batch_mode == "loop":
            import jax.numpy as _jnp
            import numpy as _np

            with self._tracer_scope() as tracer:
                t0 = time.perf_counter()
                per_sample = []
                per_sample_stats = []
                for i in range(batch):
                    with tracer.span(f"sample:{i}", cat="runtime"):
                        t_s = time.perf_counter()
                        per_sample.append(self.run(
                            {k: v[i] for k, v in inputs.items()},
                            params, interpret=interpret, jit=jit, seed=seed,
                        ))
                        ms = (time.perf_counter() - t_s) * 1e3
                    tracer.counter("sample_latency_ms", {"ms": ms})
                    if self.last_run_stats is not None:
                        per_sample_stats.append(
                            dict(self.last_run_stats, sample=i,
                                 wall_ms=round(ms, 3))
                        )
                if per_sample_stats:
                    self.last_run_stats = {
                        "samples": batch,
                        "batch_mode": "loop",
                        "wall_ms": round((time.perf_counter() - t0) * 1e3, 3),
                        "per_sample_ms": [s["wall_ms"]
                                          for s in per_sample_stats],
                        "groups": per_sample_stats[-1].get("groups", []),
                        "exec_cache": {
                            "hits": sum(s["exec_cache"]["hits"]
                                        for s in per_sample_stats),
                            "misses": sum(s["exec_cache"]["misses"]
                                          for s in per_sample_stats),
                        },
                        "dma_write_bytes":
                            per_sample_stats[-1].get("dma_write_bytes", 0),
                        "dma_read_bytes":
                            per_sample_stats[-1].get("dma_read_bytes", 0),
                    }
            # stack on device, one host conversion at the boundary
            if len(src.graph_outputs) == 1:
                return _np.asarray(_jnp.stack(per_sample))
            return {
                k: _np.asarray(_jnp.stack([o[k] for o in per_sample]))
                for k in src.graph_outputs
            }
        # random-fill only when something is actually unbound — a fully
        # parameterized call (the hot path) never pays the RNG work
        bound = set(inputs) | set(params or ())
        needed = set(src.graph_inputs) | {
            n for n, v in src.values.items() if v.is_constant
        }
        env: dict = {}
        if needed - bound:
            env.update(interp.random_env(src, seed=seed))
        if params:
            env.update(params)
        env.update(inputs)
        if batch is not None:  # batch_mode == "vmap"
            import numpy as _np

            rstats = {}
            with self._tracer_scope() as tracer:
                t0 = time.perf_counter()
                with tracer.span(f"run:{src.name}", cat="runtime") as sargs:
                    out = ops.run_compiled_batched(
                        self.design, env, batch,
                        interpret=interpret, jit=jit, stats_out=rstats)
                    sargs.update({"batch": batch,
                                  "buckets": rstats.get("batch_buckets")})
                ms = (time.perf_counter() - t0) * 1e3
                tracer.counter("batch_latency_ms", {"ms": ms})
            rstats["samples"] = batch
            rstats["batch_mode"] = "vmap"
            rstats["exec_cache_total"] = dict(ops.exec_cache_stats)
            self.last_run_stats = rstats
            # outputs stayed stacked on device; NumPy once at the boundary
            if len(src.graph_outputs) == 1:
                return _np.asarray(out[src.graph_outputs[0]])
            return {k: _np.asarray(out[k]) for k in src.graph_outputs}
        rstats = {}
        with self._tracer_scope() as tracer:
            with tracer.span(f"run:{src.name}", cat="runtime"):
                out = ops.run_compiled(self.design, env,
                                       interpret=interpret, jit=jit,
                                       stats_out=rstats)
        rstats["samples"] = 1
        rstats["exec_cache_total"] = dict(ops.exec_cache_stats)
        self.last_run_stats = rstats
        if len(src.graph_outputs) == 1:
            return out[src.graph_outputs[0]]
        return out

    @staticmethod
    def _batch_extent(src: DFG, inputs: Mapping) -> Optional[int]:
        """The shared leading batch extent when *every* bound input has
        exactly one extra leading dim over its compiled shape; ``None``
        for per-sample shapes; a loud error for anything mixed."""
        if not inputs:
            return None
        batches = set()
        for k, v in inputs.items():
            want = src.values[k].shape
            got = tuple(getattr(v, "shape", ()))
            if got == want:
                batches.add(None)
            elif len(got) == len(want) + 1 and got[1:] == want:
                batches.add(int(got[0]))
            else:
                raise ValueError(
                    f"{src.name}: input {k!r} has shape {got}; expected "
                    f"{want} or (B,) + {want} for a batched run"
                )
        if batches == {None}:
            return None
        if batches == {0}:
            raise ValueError(
                f"{src.name}: batched run with batch extent 0 — there "
                "is nothing to execute (and no dtype to shape an empty "
                "result with)"
            )
        if len(batches) != 1:
            saw = sorted(
                ("unbatched" if b is None else b for b in batches), key=str
            )
            raise ValueError(
                f"{src.name}: inconsistent batching across inputs — "
                f"every input must carry the same leading batch extent "
                f"(saw {saw})"
            )
        return batches.pop()

    # -- reporting -----------------------------------------------------------

    def report(self) -> Report:
        d = self.design
        src = d.source

        def _bytes(names) -> int:
            return sum(
                math.ceil(src.values[v].total_bits / 8) for v in names
            )

        groups = tuple(
            GroupReport(
                name=g.name,
                nodes=tuple(g.node_names),
                cycles=g.cycles,
                bram=g.bram,
                dsp=g.dsp,
                spill_in_bytes=_bytes(g.spill_in),
                spill_out_bytes=_bytes(g.spill_out),
                weight_streamed=tuple(sorted(g.weight_streamed.items())),
            )
            for g in d.groups
        )
        transitions = tuple(
            TransitionReport(
                left=left.name,
                right=right.name,
                write_bytes=w,
                read_bytes=r,
                cycles=transition_cycles(w, r),
            )
            for (left, right), (w, r) in zip(
                zip(d.groups, d.groups[1:]), d.boundary_traffic()
            )
        )
        return Report(
            graph=src.name,
            target=self.target_name,
            feasible=d.feasible,
            groups=groups,
            total_cycles=d.total_cycles,
            max_group_cycles=d.max_group_cycles,
            spill_cycles=d.spill_cycles,
            max_bram=d.max_bram,
            b_total=d.b_total,
            max_dsp=d.max_dsp,
            d_total=d.d_total,
            spill_bytes=sum(s.bytes for s in d.spills()),
            transitions=transitions,
            telemetry=self._telemetry(),
        )

    def _telemetry(self) -> Optional[dict]:
        """Measured compile/run telemetry (ISSUE 6): per-pass wall
        times, partition-DP search statistics, cumulative jit-cache
        counters, and the most recent run's counters.  ``None`` only
        for bare designs with nothing recorded."""
        import sys

        d = self.design
        tel: dict = {}
        if d.pass_result is not None:
            tel["passes"] = [
                {"name": p.name, "wall_ms": round(p.wall_ms, 3),
                 "changed": p.changed}
                for p in d.pass_result.passes
            ]
        if d.dp_stats is not None:
            tel["partition"] = d.dp_stats
        # the jit-cache counters live in repro.kernels.ops, which pulls
        # in jax — report() must stay importable without it (the
        # benchmark smoke path is model-only), so only surface the
        # counters when the kernel layer is already loaded
        ops = sys.modules.get("repro.kernels.ops")
        if ops is not None:
            tel["exec_cache"] = dict(ops.exec_cache_stats)
        if self.last_run_stats is not None:
            tel["last_run"] = self.last_run_stats
        # live aggregated series (ISSUE 10): when a metrics registry is
        # ambient, its snapshot rides in the report like every other
        # measured (compare-excluded) section
        from repro.instrument import metrics as _metrics

        reg = _metrics.current()
        if reg.enabled:
            tel["metrics"] = reg.snapshot()
        diags = self.diagnostics
        if diags:
            from repro.analyze import severity_counts

            tel["diagnostics"] = {
                "counts": severity_counts(diags),
                "items": [x.to_json() for x in diags],
            }
        return tel or None

    # -- persistence (the benchmark cache) -----------------------------------

    def save(self, path: str) -> str:
        """Pickle the compiled design (schedule IR only — cheap)."""
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(path, "wb") as f:
            pickle.dump({"version": _SAVE_VERSION, "design": self.design}, f)
        return path

    @classmethod
    def load(cls, path: str) -> "CompiledArtifact":
        with open(path, "rb") as f:
            payload = pickle.load(f)
        if not isinstance(payload, dict) or "design" not in payload:
            raise ValueError(f"{path}: not a CompiledArtifact save file")
        if payload.get("version") != _SAVE_VERSION:
            raise ValueError(
                f"{path}: save version {payload.get('version')} != "
                f"{_SAVE_VERSION} — recompile instead of loading"
            )
        return cls(payload["design"])


def compile_graph(
    graph,
    options: Optional[CompileOptions] = None,
    **option_kwargs,
) -> CompiledArtifact:
    """The front door: graph (DFG | Sequential | Graph builder) +
    options → :class:`CompiledArtifact`.

    ``option_kwargs`` are sugar for ``CompileOptions(**option_kwargs)``
    (``compile_graph(net, target="zu3eg")``); mixing them with an
    explicit ``options`` bundle is an error.
    """
    if options is not None and option_kwargs:
        raise ValueError(
            "pass either options=CompileOptions(...) or keyword knobs, "
            "not both"
        )
    if options is None:
        options = CompileOptions(**option_kwargs)
    dfg = graph.build() if hasattr(graph, "build") else graph
    if not isinstance(dfg, DFG):
        raise TypeError(
            f"compile_graph needs a DFG or a builder with .build(), got "
            f"{type(graph).__name__}"
        )
    return CompiledArtifact(compile_design(dfg, options=options))
