"""Encoder–decoder backbone (seamless-m4t style; frontend stubbed).

The speech/text frontend is a stub per the assignment: ``input_specs``
supplies precomputed frame embeddings (B, T, D).  The transformer
backbone is real: a bidirectional encoder stack + a causal decoder stack
with cross-attention over the encoder memory.  Cross-attention streams
the (fixed) memory exactly like a line buffer — K/V computed once at
prefill and reused each decode step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed.ctx import shard_activation
from . import layers as L
from .lm import chunked_ce_loss


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_enc_block(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    d, dt = cfg.d_model, cfg.param_dtype
    return {
        "ln1": jnp.ones((d,), dt),
        "attn": L.init_attention(k1, cfg),
        "ln2": jnp.ones((d,), dt),
        "mlp": L.init_mlp(k2, cfg),
    }


def _init_dec_block(key, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    d, dt = cfg.d_model, cfg.param_dtype
    return {
        "ln1": jnp.ones((d,), dt),
        "self_attn": L.init_attention(k1, cfg),
        "ln_x": jnp.ones((d,), dt),
        "cross_attn": L.init_attention(k2, cfg),
        "ln2": jnp.ones((d,), dt),
        "mlp": L.init_mlp(k3, cfg),
    }


def init_params(key, cfg: ModelConfig) -> dict:
    ke, kd, kemb, kh = jax.random.split(key, 4)
    d, v, dt = cfg.d_model, cfg.vocab_size, cfg.param_dtype
    enc = jax.vmap(lambda k: _init_enc_block(k, cfg))(
        jax.random.split(ke, cfg.enc_layers)
    )
    dec = jax.vmap(lambda k: _init_dec_block(k, cfg))(
        jax.random.split(kd, cfg.dec_layers)
    )
    return {
        "encoder": {"blocks": enc, "final_norm": jnp.ones((d,), dt)},
        "decoder": {"blocks": dec, "final_norm": jnp.ones((d,), dt)},
        "embed": L.dense_init(kemb, (v, d), dt, scale=0.02),
        "lm_head": L.dense_init(kh, (d, v), dt),
    }


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------


def encode(params: dict, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, T, D) stub embeddings → encoder memory (B, T, D)."""
    h = shard_activation(frames.astype(cfg.param_dtype), "hidden")
    bsz, t = h.shape[0], h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (bsz, t))

    def body(hh, p):
        a, _ = L.attention_layer(
            p["attn"], cfg, L.rmsnorm(hh, p["ln1"], cfg.norm_eps), positions,
            causal=False,
        )
        hh = hh + a
        hh = hh + L.mlp_layer(p["mlp"], cfg,
                              L.rmsnorm(hh, p["ln2"], cfg.norm_eps))
        return shard_activation(hh, "hidden"), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = lax.scan(body, h, params["encoder"]["blocks"])
    return L.rmsnorm(h, params["encoder"]["final_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------


def _cross_kv(p: dict, cfg: ModelConfig, memory: jax.Array):
    hd = cfg.resolved_head_dim
    k = memory @ p["wk"]
    v = memory @ p["wv"]
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    k = k.reshape(*memory.shape[:2], cfg.num_kv_heads, hd).transpose(0, 2, 1, 3)
    v = v.reshape(*memory.shape[:2], cfg.num_kv_heads, hd).transpose(0, 2, 1, 3)
    return k, v


def decode_train(
    params: dict, cfg: ModelConfig, memory: jax.Array, tokens: jax.Array
) -> jax.Array:
    """Teacher-forced decoder forward: (B, S) tokens → hidden (B, S, D)."""
    h = shard_activation(params["embed"][tokens], "hidden")
    bsz, s = h.shape[0], h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (bsz, s))

    def body(hh, p):
        a, _ = L.attention_layer(
            p["self_attn"], cfg, L.rmsnorm(hh, p["ln1"], cfg.norm_eps),
            positions, causal=True,
        )
        hh = hh + a
        ck, cv = _cross_kv(p["cross_attn"], cfg, memory)
        c, _ = L.attention_layer(
            p["cross_attn"], cfg, L.rmsnorm(hh, p["ln_x"], cfg.norm_eps),
            positions, causal=False, kv_override=(ck, cv),
        )
        hh = hh + c
        hh = hh + L.mlp_layer(p["mlp"], cfg,
                              L.rmsnorm(hh, p["ln2"], cfg.norm_eps))
        return shard_activation(hh, "hidden"), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = lax.scan(body, h, params["decoder"]["blocks"])
    return L.rmsnorm(h, params["decoder"]["final_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


def encdec_loss(params: dict, cfg: ModelConfig, batch: dict) -> jax.Array:
    memory = encode(params, cfg, batch["frames"])
    h = decode_train(params, cfg, memory, batch["tokens"])
    return chunked_ce_loss(h, params["lm_head"], batch["labels"],
                           cfg.loss_chunk,
                           streaming_bwd=cfg.loss_streaming_bwd)


def encdec_prefill(params: dict, cfg: ModelConfig, batch: dict):
    """Encode + cache cross-K/V per decoder layer + first-token logits."""
    memory = encode(params, cfg, batch["frames"])

    def per_layer(p):
        return _cross_kv(p["cross_attn"], cfg, memory)

    ck, cv = jax.vmap(per_layer)(params["decoder"]["blocks"])
    bsz = memory.shape[0]
    bos = jnp.zeros((bsz,), jnp.int32)
    hd = cfg.resolved_head_dim
    self_k = jnp.zeros(
        (cfg.dec_layers, bsz, cfg.num_kv_heads, 1, hd), cfg.param_dtype
    )
    cache = {"ck": ck, "cv": cv, "k": self_k, "v": self_k}
    logits, cache = encdec_decode(params, cfg, cache, bos,
                                  jnp.zeros((), jnp.int32))
    return logits, cache


def encdec_decode(
    params: dict,
    cfg: ModelConfig,
    cache: dict,     # {"ck","cv": (Ld,B,Hkv,T,hd), "k","v": (Ld,B,Hkv,S,hd)}
    token: jax.Array,    # (B,)
    pos: jax.Array,      # ()
):
    h = params["embed"][token][:, None, :]

    def body(hh, xs):
        p, ck, cv, sk, sv = xs
        a, nk, nv = L.attention_decode(
            p["self_attn"], cfg, L.rmsnorm(hh, p["ln1"], cfg.norm_eps),
            pos, sk, sv,
        )
        hh = hh + a
        c, _, _ = L.attention_decode(
            p["cross_attn"], cfg, L.rmsnorm(hh, p["ln_x"], cfg.norm_eps),
            pos, ck, cv, cross=True,
        )
        hh = hh + c
        hh = hh + L.mlp_layer(p["mlp"], cfg,
                              L.rmsnorm(hh, p["ln2"], cfg.norm_eps))
        return hh, (nk, nv)

    h, (nk, nv) = lax.scan(
        body, h,
        (params["decoder"]["blocks"], cache["ck"], cache["cv"],
         cache["k"], cache["v"]),
    )
    h = L.rmsnorm(h, params["decoder"]["final_norm"], cfg.norm_eps)
    logits = (h[:, 0] @ params["lm_head"]).astype(jnp.float32)
    return logits, {"ck": cache["ck"], "cv": cache["cv"], "k": nk, "v": nv}


def init_cache(cfg: ModelConfig, batch: int, mem_len: int, max_len: int):
    hd = cfg.resolved_head_dim
    dt = cfg.param_dtype
    kv = jnp.zeros((cfg.dec_layers, batch, cfg.num_kv_heads, max_len, hd), dt)
    ckv = jnp.zeros((cfg.dec_layers, batch, cfg.num_kv_heads, mem_len, hd), dt)
    return {"ck": ckv, "cv": ckv, "k": kv, "v": kv}
