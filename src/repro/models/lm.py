"""Decoder-only LM covering the dense / moe / ssm / hybrid families.

Layer stacks are *scanned* (``lax.scan`` over stacked parameters) so the
HLO stays O(1) in depth — essential for compiling 16–80-layer models at
512 host devices in the dry-run, and the standard production structure
for remat.  Heterogeneous stacks (Jamba's 1-attn-per-8 with alternating
MoE) scan over *super-blocks*: the smallest repeating layer pattern.

Three entry points per arch (all pure functions of (params, inputs)):
  ``lm_loss``      — training forward + chunked CE loss
  ``lm_prefill``   — full-sequence forward, returns last-token logits +
                     the caches (KV / conv+SSM state) for decode
  ``lm_decode``    — one-token step against the bounded caches
"""
from __future__ import annotations

import dataclasses
import functools
import math
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed.ctx import shard_activation
from . import layers as L
from . import mamba2 as M
from . import moe as MOE


# ---------------------------------------------------------------------------
# layer pattern
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LayerSpec:
    mixer: str            # "attn" | "mamba"
    ffn: Optional[str]    # "mlp" | "moe" | None


def superblock_pattern(cfg: ModelConfig) -> list[LayerSpec]:
    if cfg.family in ("dense", "vlm", "audio"):
        return [LayerSpec("attn", "mlp")]
    if cfg.family == "moe":
        return [LayerSpec("attn", "moe")]
    if cfg.family == "ssm":
        return [LayerSpec("mamba", None)]
    if cfg.family == "hybrid":
        assert cfg.attn_period > 0 and cfg.moe is not None
        pat = []
        for i in range(cfg.attn_period):
            mixer = "attn" if i == cfg.attn_period // 2 else "mamba"
            is_moe = (i % cfg.moe.moe_period) == (cfg.moe.moe_period - 1)
            pat.append(LayerSpec(mixer, "moe" if is_moe else "mlp"))
        return pat
    raise ValueError(cfg.family)


def num_superblocks(cfg: ModelConfig) -> int:
    pat = superblock_pattern(cfg)
    assert cfg.num_layers % len(pat) == 0, (cfg.num_layers, len(pat))
    return cfg.num_layers // len(pat)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_block(key, cfg: ModelConfig, spec: LayerSpec) -> dict:
    ks = jax.random.split(key, 4)
    d, dt = cfg.d_model, cfg.param_dtype
    p: dict = {"ln1": jnp.ones((d,), dt)}
    if spec.mixer == "attn":
        p["attn"] = L.init_attention(ks[0], cfg)
    else:
        p["mamba"] = M.init_mamba(ks[0], cfg)
    if spec.ffn is not None:
        p["ln2"] = jnp.ones((d,), dt)
        if spec.ffn == "mlp":
            p["mlp"] = L.init_mlp(ks[1], cfg)
        else:
            p["moe"] = MOE.init_moe(ks[1], cfg)
    return p


def init_params(key, cfg: ModelConfig) -> dict:
    pat = superblock_pattern(cfg)
    nsb = num_superblocks(cfg)
    d, v, dt = cfg.d_model, cfg.padded_vocab, cfg.param_dtype
    k_embed, k_head, k_blocks = jax.random.split(key, 3)

    def one_superblock(k):
        kk = jax.random.split(k, len(pat))
        return {f"b{i}": _init_block(kk[i], cfg, s) for i, s in enumerate(pat)}

    blocks = jax.vmap(one_superblock)(jax.random.split(k_blocks, nsb))
    params = {
        "blocks": blocks,
        "final_norm": jnp.ones((d,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(k_head, (d, v), dt)
    if not cfg.embeds_input:
        params["embed"] = L.dense_init(k_embed, (v, d), dt, scale=0.02)
    return params


def _head_matrix(params: dict) -> jax.Array:
    """(D, V) output projection — the transposed embedding when tied."""
    if "lm_head" in params:
        return params["lm_head"]
    return params["embed"].T


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------


def _apply_block(
    p: dict,
    cfg: ModelConfig,
    spec: LayerSpec,
    h: jax.Array,
    positions: jax.Array,
    mrope_positions,
    collect_cache: bool,
):
    cache = None
    if spec.mixer == "attn":
        a, (k, v) = L.attention_layer(
            p["attn"], cfg, L.rmsnorm(h, p["ln1"], cfg.norm_eps), positions,
            causal=True, mrope_positions=mrope_positions,
        )
        if collect_cache:
            cache = {"k": k, "v": v}
    else:
        a, st = _mamba_forward(p["mamba"], cfg, L.rmsnorm(h, p["ln1"],
                                                          cfg.norm_eps),
                               collect_cache)
        cache = st
    h = h + a
    if spec.ffn is not None:
        x = L.rmsnorm(h, p["ln2"], cfg.norm_eps)
        if spec.ffn == "mlp":
            f = L.mlp_layer(p["mlp"], cfg, x)
        else:
            f = MOE.moe_layer(p["moe"], cfg, x)
        h = h + f
    h = shard_activation(h, "hidden")
    return h, cache


def _mamba_forward(p, cfg, x, collect_cache):
    if not collect_cache:
        return M.mamba_layer(p, cfg, x), None
    # prefill: also produce (conv line buffer, SSM state) for decode
    s = cfg.ssm
    b, l, d = x.shape
    di = s.d_inner(d)
    n = s.state_dim
    z, xbc, dt = M._split_proj(cfg, x @ p["in_proj"])
    conv_cache = xbc[:, -(s.conv_kernel - 1):, :]            # (B, K-1, CD)
    xbc_c = jax.nn.silu(M._causal_depthwise_conv(xbc, p["conv_w"]))
    xs = xbc_c[..., :di].reshape(b, l, s.num_heads(d), s.head_dim)
    b_mat = xbc_c[..., di : di + n]
    c_mat = xbc_c[..., di + n :]
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    from repro.kernels import ref as kref

    y, ssm_state = kref.ssd_chunked(xs, dtf, a, b_mat, c_mat,
                                    chunk=M.pick_chunk(l, s.chunk))
    y = y + xs.astype(jnp.float32) * p["skip_d"][None, None, :, None]
    y = y.reshape(b, l, di).astype(x.dtype)
    y = L.rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                  p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"], {"conv": conv_cache, "ssm": ssm_state}


def _apply_block_decode(
    p: dict,
    cfg: ModelConfig,
    spec: LayerSpec,
    h: jax.Array,            # (B, 1, D)
    pos: jax.Array,          # () int32
    cache: dict,
):
    if spec.mixer == "attn":
        a, k_new, v_new = L.attention_decode(
            p["attn"], cfg, L.rmsnorm(h, p["ln1"], cfg.norm_eps), pos,
            cache["k"], cache["v"],
        )
        new_cache = {"k": k_new, "v": v_new}
    else:
        a, conv, ssm = M.mamba_decode(
            p["mamba"], cfg, L.rmsnorm(h, p["ln1"], cfg.norm_eps),
            cache["conv"], cache["ssm"],
        )
        new_cache = {"conv": conv, "ssm": ssm}
    h = h + a
    if spec.ffn is not None:
        x = L.rmsnorm(h, p["ln2"], cfg.norm_eps)
        if spec.ffn == "mlp":
            f = L.mlp_layer(p["mlp"], cfg, x)
        else:
            f = MOE.moe_layer(p["moe"], cfg, x)
        h = h + f
    return h, new_cache


# ---------------------------------------------------------------------------
# backbone (scan over superblocks)
# ---------------------------------------------------------------------------


def backbone(
    params: dict,
    cfg: ModelConfig,
    h: jax.Array,
    positions: jax.Array,
    mrope_positions=None,
    collect_cache: bool = False,
):
    pat = superblock_pattern(cfg)

    def body(hh, block_p):
        caches = {}
        for i, spec in enumerate(pat):
            hh, c = _apply_block(
                block_p[f"b{i}"], cfg, spec, hh, positions,
                mrope_positions, collect_cache,
            )
            if collect_cache:
                caches[f"b{i}"] = c
        return hh, (caches if collect_cache else None)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, caches = lax.scan(body, h, params["blocks"])
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return h, caches


# ---------------------------------------------------------------------------
# losses / entry points
# ---------------------------------------------------------------------------


def _ce_chunk_terms(h, lm_head, labels, t, chunk, valid_vocab=None):
    """(Σ(logz - gold), logz) for chunk t — shared by fwd and bwd."""
    hs = lax.dynamic_slice_in_dim(h, t * chunk, chunk, axis=1)
    ls = lax.dynamic_slice_in_dim(labels, t * chunk, chunk, axis=1)
    logits = (hs @ lm_head).astype(jnp.float32)              # (B, c, V)
    logits = shard_activation(logits, "logits")
    if valid_vocab is not None and valid_vocab < logits.shape[-1]:
        # vocab-padding (§Perf): padded columns never win the softmax
        pad_mask = jnp.arange(logits.shape[-1]) < valid_vocab
        logits = jnp.where(pad_mask[None, None], logits, -1e30)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, ls[..., None], axis=-1)[..., 0]
    return jnp.sum(logz - gold), (hs, ls, logits, logz)


def _chunked_ce_scan(h, lm_head, labels, chunk, valid_vocab=None):
    nc = h.shape[1] // chunk

    def step(acc, t):
        term, _ = _ce_chunk_terms(h, lm_head, labels, t, chunk, valid_vocab)
        return acc + term, None

    total, _ = lax.scan(step, jnp.zeros((), jnp.float32), jnp.arange(nc))
    return total / (h.shape[0] * h.shape[1])


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _chunked_ce_streaming(h, lm_head, labels, chunk, valid_vocab=None):
    """Chunked CE with a *streaming backward*: the default scan VJP would
    stash every (B, c, V) logits chunk — the full (B, S, V) tensor — for
    the backward.  This VJP saves only (h, lm_head, labels) and
    recomputes per-chunk logits, emitting dh and a running dW (the
    Liger-style fused cross-entropy, i.e. MING C1 at the loss layer)."""
    return _chunked_ce_scan(h, lm_head, labels, chunk, valid_vocab)


def _chunked_ce_fwd(h, lm_head, labels, chunk, valid_vocab=None):
    return (_chunked_ce_scan(h, lm_head, labels, chunk, valid_vocab),
            (h, lm_head, labels))


def _chunked_ce_bwd(chunk, valid_vocab, res, ct):
    h, lm_head, labels = res
    b, s, d = h.shape
    nc = s // chunk
    scale = ct / (b * s)                                      # dloss/dlogit pre-softmax

    def step(carry, t):
        dh_acc, dw_acc = carry
        _, (hs, ls, logits, logz) = _ce_chunk_terms(h, lm_head, labels,
                                                     t, chunk, valid_vocab)
        p = jnp.exp(logits - logz[..., None])                 # softmax (B,c,V)
        onehot = jax.nn.one_hot(ls, logits.shape[-1], dtype=jnp.float32)
        dlogits = (p - onehot) * scale                        # (B,c,V)
        dh_chunk = jnp.einsum(
            "bcv,dv->bcd", dlogits, lm_head.astype(jnp.float32)
        )
        dw_acc = dw_acc + jnp.einsum(
            "bcd,bcv->dv", hs.astype(jnp.float32), dlogits
        )
        dh_acc = lax.dynamic_update_slice_in_dim(
            dh_acc, dh_chunk.astype(h.dtype), t * chunk, axis=1
        )
        return (dh_acc, dw_acc), None

    dh0 = jnp.zeros_like(h)
    dw0 = jnp.zeros((d, lm_head.shape[1]), jnp.float32)
    (dh, dw), _ = lax.scan(step, (dh0, dw0), jnp.arange(nc))
    return dh, dw.astype(lm_head.dtype), None


_chunked_ce_streaming.defvjp(_chunked_ce_fwd, _chunked_ce_bwd)


def chunked_ce_loss(
    h: jax.Array,            # (B, S, D)
    lm_head: jax.Array,      # (D, V)
    labels: jax.Array,       # (B, S) int32
    chunk: int,
    streaming_bwd: bool = True,
    valid_vocab: int | None = None,
) -> jax.Array:
    """Cross-entropy streamed over sequence chunks: the (B, S, V) logits
    tensor — by far the largest train-time intermediate at 128–256k
    vocabs — is never materialized (MING C1 at the loss layer), in the
    backward pass either (``streaming_bwd``)."""
    b, s, d = h.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    if streaming_bwd:
        return _chunked_ce_streaming(h, lm_head, labels, chunk, valid_vocab)
    return _chunked_ce_scan(h, lm_head, labels, chunk, valid_vocab)


def _embed_in(params, cfg, tokens_or_embeds):
    if cfg.embeds_input:
        h = tokens_or_embeds.astype(cfg.param_dtype)
    else:
        h = params["embed"][tokens_or_embeds]
    return shard_activation(h, "hidden")


def lm_loss(
    params: dict,
    cfg: ModelConfig,
    batch: dict,
) -> jax.Array:
    """batch: {"tokens" | "embeds", "labels", optional "mrope_positions"}."""
    x = batch["embeds"] if cfg.embeds_input else batch["tokens"]
    h = _embed_in(params, cfg, x)
    bsz, s = h.shape[0], h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (bsz, s))
    h, _ = backbone(
        params, cfg, h, positions,
        mrope_positions=batch.get("mrope_positions"), collect_cache=False,
    )
    return chunked_ce_loss(h, _head_matrix(params), batch["labels"],
                           cfg.loss_chunk,
                           streaming_bwd=cfg.loss_streaming_bwd,
                           valid_vocab=cfg.vocab_size
                           if cfg.padded_vocab != cfg.vocab_size else None)


def lm_prefill(params: dict, cfg: ModelConfig, batch: dict):
    """Returns (last-token logits (B, V), caches) — serving prefill."""
    x = batch["embeds"] if cfg.embeds_input else batch["tokens"]
    h = _embed_in(params, cfg, x)
    bsz, s = h.shape[0], h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (bsz, s))
    h, caches = backbone(
        params, cfg, h, positions,
        mrope_positions=batch.get("mrope_positions"), collect_cache=True,
    )
    logits = (h[:, -1] @ _head_matrix(params)).astype(jnp.float32)
    return logits[..., : cfg.vocab_size], caches


def lm_decode(
    params: dict,
    cfg: ModelConfig,
    cache: dict,             # stacked (n_super, ...) cache pytree
    token: jax.Array,        # (B,) int32 — or (B, 1, D) embeds
    pos: jax.Array,          # () int32 absolute position
):
    """One decode step. Returns (logits (B, V), new_cache)."""
    pat = superblock_pattern(cfg)
    if cfg.embeds_input:
        h = token.astype(cfg.param_dtype)
        if h.ndim == 2:
            h = h[:, None, :]
    else:
        h = params["embed"][token][:, None, :]               # (B, 1, D)

    def body(hh, xs):
        block_p, cache_slice = xs
        new_slices = {}
        for i, spec in enumerate(pat):
            hh, nc = _apply_block_decode(
                block_p[f"b{i}"], cfg, spec, hh, pos, cache_slice[f"b{i}"]
            )
            new_slices[f"b{i}"] = nc
        return hh, new_slices

    h, new_cache = lax.scan(body, h, (params["blocks"], cache))
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = (h[:, 0] @ _head_matrix(params)).astype(jnp.float32)
    return logits[..., : cfg.vocab_size], new_cache


# ---------------------------------------------------------------------------
# cache allocation (decode entry without a real prefill — dry-run shapes)
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Zeroed caches shaped exactly as lm_prefill would produce them."""
    pat = superblock_pattern(cfg)
    nsb = num_superblocks(cfg)
    hd = cfg.resolved_head_dim
    dt = cfg.param_dtype
    out = {}
    for i, spec in enumerate(pat):
        if spec.mixer == "attn":
            kv = jnp.zeros((nsb, batch, cfg.num_kv_heads, max_len, hd), dt)
            out[f"b{i}"] = {"k": kv, "v": kv}
        else:
            s = cfg.ssm
            out[f"b{i}"] = {
                "conv": jnp.zeros(
                    (nsb, batch, s.conv_kernel - 1, s.conv_dim(cfg.d_model)),
                    dt,
                ),
                "ssm": jnp.zeros(
                    (nsb, batch, s.num_heads(cfg.d_model), s.head_dim,
                     s.state_dim),
                    jnp.float32,
                ),
            }
    return out
