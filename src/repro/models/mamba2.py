"""Mamba-2 block (SSD — state-space duality, arXiv:2405.21060).

The SSM family is the strongest match to the paper's thesis (DESIGN.md
§4): both the depthwise causal conv (a literal K-1 line buffer over
time) and the SSD recurrent state (an O(1)-per-step carry replacing the
O(L²) attention intermediate) are streaming structures.  Decode carries
exactly (conv window, SSM state) — the whole "KV cache" is a line buffer.

Train/prefill use the chunked SSD scan (``repro.kernels.ref.ssd_chunked``,
the same algorithm the Pallas kernel implements); decode uses the O(1)
recurrent step.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.kernels import ref as kref
from .layers import dense_init, rmsnorm


def init_mamba(key, cfg: ModelConfig) -> dict:
    s = cfg.ssm
    assert s is not None
    d, dt_ = cfg.d_model, cfg.param_dtype
    di = s.d_inner(d)
    h = s.num_heads(d)
    cd = s.conv_dim(d)
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * s.state_dim + h), dt_),
        "conv_w": dense_init(ks[1], (s.conv_kernel, cd), dt_, scale=0.5),
        "a_log": jnp.zeros((h,), jnp.float32),          # A = -exp(a_log) = -1
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "skip_d": jnp.ones((h,), jnp.float32),
        "norm_w": jnp.ones((di,), dt_),
        "out_proj": dense_init(ks[2], (di, d), dt_),
    }


def pick_chunk(l: int, target: int) -> int:
    """Largest divisor of ``l`` that is ≤ target (SSD needs chunk | L)."""
    c = max(min(target, l), 1)
    while l % c:
        c -= 1
    return c


def _causal_depthwise_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (B, L, C); w: (K, C). Left-padded causal depthwise conv —
    K-1 rows of history: the 1-D line buffer."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    l = x.shape[1]
    for i in range(k):
        out = out + xp[:, i : i + l].astype(jnp.float32) * w[i].astype(
            jnp.float32
        )
    return out.astype(x.dtype)


def _conv_decode_step(
    x_t: jax.Array,          # (B, C) new element
    conv_cache: jax.Array,   # (B, K-1, C) line buffer
    w: jax.Array,            # (K, C)
) -> tuple[jax.Array, jax.Array]:
    window = jnp.concatenate([conv_cache, x_t[:, None]], axis=1)   # (B, K, C)
    out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                     w.astype(jnp.float32))
    return out.astype(x_t.dtype), window[:, 1:]


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    h = s.num_heads(cfg.d_model)
    n = s.state_dim
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : 2 * di + 2 * n]
    dt = zxbcdt[..., 2 * di + 2 * n :]
    assert dt.shape[-1] == h
    return z, xbc, dt


def mamba_layer(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Full-sequence forward: (B, L, D) → (B, L, D)."""
    s = cfg.ssm
    b, l, d = x.shape
    di = s.d_inner(d)
    h = s.num_heads(d)
    n = s.state_dim

    z, xbc, dt = _split_proj(cfg, x @ p["in_proj"])
    xbc = jax.nn.silu(_causal_depthwise_conv(xbc, p["conv_w"]))
    xs = xbc[..., :di].reshape(b, l, h, s.head_dim)
    b_mat = xbc[..., di : di + n]
    c_mat = xbc[..., di + n :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])

    a = -jnp.exp(p["a_log"])
    chunk = pick_chunk(l, s.chunk)
    y, _ = kref.ssd_chunked(xs, dt, a, b_mat, c_mat, chunk=chunk)
    y = y + xs.astype(jnp.float32) * p["skip_d"][None, None, :, None]
    y = y.reshape(b, l, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"]


def mamba_decode(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,            # (B, 1, D)
    conv_cache: jax.Array,   # (B, K-1, conv_dim)
    ssm_state: jax.Array,    # (B, H, P, N) f32
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """O(1) recurrent step; returns (out, new_conv_cache, new_ssm_state)."""
    s = cfg.ssm
    b = x.shape[0]
    d = cfg.d_model
    di = s.d_inner(d)
    h = s.num_heads(d)
    n = s.state_dim

    z, xbc, dt = _split_proj(cfg, x[:, 0] @ p["in_proj"])
    xbc, conv_cache = _conv_decode_step(xbc, conv_cache, p["conv_w"])
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :di].reshape(b, h, s.head_dim)
    b_t = xbc[..., di : di + n]
    c_t = xbc[..., di + n :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B, H)

    a = -jnp.exp(p["a_log"])
    y, ssm_state = kref.ssd_decode_step(ssm_state, xs, dt, a, b_t, c_t)
    y = y + xs.astype(jnp.float32) * p["skip_d"][None, :, None]
    y = y.reshape(b, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                p["norm_w"], cfg.norm_eps)
    return (y @ p["out_proj"])[:, None], conv_cache, ssm_state
