"""Transformer building blocks (pure JAX, shardable, scan-friendly).

Attention ships three interchangeable implementations:

* ``blockwise`` — KV tiles stream through a ``lax.scan`` with running
  (m, l, acc) state: flash attention expressed in XLA.  This is MING's
  streaming architecture at the graph level — the (Sq, Sk) score matrix
  (the "intermediate tensor") is never materialized in HBM.  Used for
  training and prefill, and it is what the dry-run lowers, so the
  roofline memory term reflects streaming behaviour.
* ``reference`` — dense einsum softmax (oracle; small shapes only).
* ``pallas`` — the ``repro.kernels.flash_attention`` TPU kernel (fast
  path on real hardware; validated in interpret mode).

Decode (Sq == 1) always uses the bounded-KV-cache einsum path: one new
token against a position-masked cache — HBM-bound by design, which is
the correct roofline profile for decode.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * lax.rsqrt(var + eps)).astype(x.dtype) * w


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def _rope_inv_freq(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def rope_cos_sin(
    positions: jax.Array,     # (B, S) int32
    head_dim: int,
    theta: float,
    mrope_sections: tuple[int, ...] = (),
    mrope_positions: jax.Array | None = None,   # (3, B, S) for M-RoPE
) -> tuple[jax.Array, jax.Array]:
    """Returns cos/sin of shape (B, S, head_dim/2), fp32.

    M-RoPE (Qwen2-VL, arXiv:2409.12191): the head_dim/2 frequency slots
    are split into (t, h, w) sections; each section rotates by its own
    position stream.  Text-only tokens pass identical streams.
    """
    inv = _rope_inv_freq(head_dim, theta)                 # (hd/2,)
    if mrope_sections:
        assert mrope_positions is not None
        assert sum(mrope_sections) == head_dim // 2, (
            mrope_sections, head_dim)
        pieces = []
        off = 0
        for axis, sec in enumerate(mrope_sections):
            p = mrope_positions[axis].astype(jnp.float32)  # (B, S)
            pieces.append(p[..., None] * inv[off : off + sec][None, None])
            off += sec
        ang = jnp.concatenate(pieces, axis=-1)             # (B, S, hd/2)
    else:
        ang = positions.astype(jnp.float32)[..., None] * inv[None, None]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, H, S, hd); cos/sin: (B, S, hd/2). Rotate-half convention."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[:, None].astype(jnp.float32)
    s = sin[:, None].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention implementations
# ---------------------------------------------------------------------------


def attention_reference(
    q, k, v, *, causal: bool = True, q_offset: int = 0
) -> jax.Array:
    from repro.kernels import ref

    return ref.attention(q, k, v, causal=causal, q_offset=q_offset)


def _divisor_block(size: int, target: int) -> int:
    b = max(min(target, size), 1)
    while size % b:
        b -= 1
    return b


def _flash_forward_blocks(qb, kb, vb, *, causal, q_offset, block_q, block_k):
    """Shared forward: qb (B,Hkv,g,nq,bq,D) pre-scaled; kb/vb
    (B,Hkv,nk,bk,D).  Returns (out (B,Hkv,g,nq,bq,D) f32,
    lse (B,Hkv,g,nq,bq) f32)."""
    b, hkv, g, nq, bq, d = qb.shape
    nk = kb.shape[2]

    def one_q_block(qi):
        qc = qb[:, :, :, qi].astype(jnp.float32)              # (B,Hkv,g,bq,D)
        qpos = qi * block_q + jnp.arange(block_q) + q_offset  # (bq,)

        def kv_step(carry, ki):
            m, l, acc = carry
            kc = kb[:, :, ki].astype(jnp.float32)             # (B,Hkv,bk,D)
            vc = vb[:, :, ki].astype(jnp.float32)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qc, kc)
            if causal:
                # additive (bq, bk) bias used ONCE — a boolean mask used
                # twice (where on s and on p) gets loop-hoisted by XLA as
                # a stacked, batch-broadcast pred tensor (measured: 9.7 GB
                # at 4k/512 blocks; EXPERIMENTS.md §Perf iteration 2)
                kpos = ki * block_k + jnp.arange(block_k)
                bias = jnp.where(
                    qpos[:, None] >= kpos[None, :], 0.0, NEG_INF
                )                                              # (bq, bk) f32
                s = s + bias[None, None, None]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])                  # masked → 0
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vc
            )
            return (m_new, l, acc), None

        m0 = jnp.full((b, hkv, g, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, block_q), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, block_q, d), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        safe_l = jnp.where(l > 0, l, 1.0)
        lse = m + jnp.log(safe_l)                              # (B,Hkv,g,bq)
        return acc / safe_l[..., None], lse

    if nq == 1:
        o, lse = one_q_block(0)
        return o[:, :, :, None], lse[:, :, :, None]
    o, lse = lax.map(one_q_block, jnp.arange(nq))
    return jnp.moveaxis(o, 0, 3), jnp.moveaxis(lse, 0, 3)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _blockwise_attention_core(q, k, v, causal, q_offset, block_q, block_k):
    """Flash attention with a *streaming backward* (MING C1 applied to
    training): the default scan VJP would stash every (bq, bk) score
    block — the full O(Sq·Sk) attention matrix — for the backward pass.
    This custom VJP saves only (q, k, v, out, lse) and recomputes score
    blocks on the fly, keeping train-time memory O(S·D).  Measured
    before/after in EXPERIMENTS.md §Perf (llama train_4k)."""
    out, _ = _blockwise_attention_fwd(
        q, k, v, causal, q_offset, block_q, block_k
    )
    return out


def _blockwise_attention_fwd(q, k, v, causal, q_offset, block_q, block_k):
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    nq, nk = sq // block_q, sk // block_k
    scale = d ** -0.5
    qb = (q * scale).reshape(b, hkv, g, nq, block_q, d)
    kb = k.reshape(b, hkv, nk, block_k, d)
    vb = v.reshape(b, hkv, nk, block_k, d)
    o, lse = _flash_forward_blocks(
        qb, kb, vb, causal=causal, q_offset=q_offset,
        block_q=block_q, block_k=block_k,
    )
    out = o.reshape(b, hq, sq, d).astype(q.dtype)
    return out, (q, k, v, out, lse)


def _blockwise_attention_bwd(causal, q_offset, block_q, block_k, res, dout):
    q, k, v, out, lse = res
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    nq, nk = sq // block_q, sk // block_k
    scale = d ** -0.5

    qb = (q * scale).reshape(b, hkv, g, nq, block_q, d)
    kb = k.reshape(b, hkv, nk, block_k, d)
    vb = v.reshape(b, hkv, nk, block_k, d)
    dob = dout.reshape(b, hkv, g, nq, block_q, d)
    ob = out.reshape(b, hkv, g, nq, block_q, d)
    # D_i = rowsum(dout ⊙ out) — the softmax-jacobian diagonal term
    delta = jnp.sum(
        dob.astype(jnp.float32) * ob.astype(jnp.float32), axis=-1
    )                                                          # (B,Hkv,g,nq,bq)

    dk0 = jnp.zeros((b, hkv, sk, d), jnp.float32)
    dv0 = jnp.zeros((b, hkv, sk, d), jnp.float32)

    def q_block_step(carry, qi):
        dk_acc, dv_acc = carry
        qc = qb[:, :, :, qi].astype(jnp.float32)               # (B,Hkv,g,bq,D)
        doc = dob[:, :, :, qi].astype(jnp.float32)
        lsec = lse[:, :, :, qi]                                # (B,Hkv,g,bq)
        dc = delta[:, :, :, qi]
        qpos = qi * block_q + jnp.arange(block_q) + q_offset

        def kv_step(carry2, ki):
            dq_acc, dk_a, dv_a = carry2
            kc = kb[:, :, ki].astype(jnp.float32)              # (B,Hkv,bk,D)
            vc = vb[:, :, ki].astype(jnp.float32)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qc, kc)
            if causal:
                kpos = ki * block_k + jnp.arange(block_k)
                bias = jnp.where(
                    qpos[:, None] >= kpos[None, :], 0.0, NEG_INF
                )
                s = s + bias[None, None, None]
            p = jnp.exp(s - lsec[..., None])                   # masked → 0
            # dv_k += Σ_g p^T do ; dp = do v^T ; ds = p (dp - D)
            dv_blk = jnp.einsum("bhgqk,bhgqd->bhkd", p, doc)
            dp = jnp.einsum("bhgqd,bhkd->bhgqk", doc, vc)
            ds = p * (dp - dc[..., None])
            dq_acc = dq_acc + jnp.einsum("bhgqk,bhkd->bhgqd", ds, kc)
            dk_blk = jnp.einsum("bhgqk,bhgqd->bhkd", ds, qc)
            dk_a = lax.dynamic_update_slice_in_dim(
                dk_a, lax.dynamic_slice_in_dim(dk_a, ki * block_k, block_k, 2)
                + dk_blk, ki * block_k, axis=2,
            )
            dv_a = lax.dynamic_update_slice_in_dim(
                dv_a, lax.dynamic_slice_in_dim(dv_a, ki * block_k, block_k, 2)
                + dv_blk, ki * block_k, axis=2,
            )
            return (dq_acc, dk_a, dv_a), None

        dq0 = jnp.zeros((b, hkv, g, block_q, d), jnp.float32)
        (dq_blk, dk_acc, dv_acc), _ = lax.scan(
            kv_step, (dq0, dk_acc, dv_acc), jnp.arange(nk)
        )
        return (dk_acc, dv_acc), dq_blk

    (dk, dv), dq_blocks = lax.scan(q_block_step, (dk0, dv0), jnp.arange(nq))
    dq = jnp.moveaxis(dq_blocks, 0, 3)                         # (B,Hkv,g,nq,bq,D)
    dq = (dq * scale).reshape(b, hq, sq, d).astype(q.dtype)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


_blockwise_attention_core.defvjp(
    lambda q, k, v, causal, q_offset, block_q, block_k: _blockwise_attention_fwd(
        q, k, v, causal, q_offset, block_q, block_k
    ),
    _blockwise_attention_bwd,
)


def blockwise_attention(
    q: jax.Array,      # (B, Hq, Sq, D)
    k: jax.Array,      # (B, Hkv, Sk, D)
    v: jax.Array,      # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    q_offset: int = 0,
    block_q: int = 512,
    block_k: int = 512,
    streaming_bwd: bool = True,
) -> jax.Array:
    """Streaming flash attention in XLA (see module docstring).

    ``streaming_bwd=False`` falls back to the default scan VJP (which
    materializes every score block in the backward) — kept selectable for
    the §Perf before/after measurement.
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    # largest divisors ≤ requested block (production shapes divide exactly;
    # odd serving lengths degrade gracefully instead of asserting)
    block_q = _divisor_block(sq, block_q)
    block_k = _divisor_block(sk, block_k)
    if streaming_bwd:
        return _blockwise_attention_core(
            q, k, v, causal, q_offset, block_q, block_k
        )
    g = hq // hkv
    nq = sq // block_q
    scale = d ** -0.5
    qb = (q * scale).reshape(b, hkv, g, nq, block_q, d)
    kb = k.reshape(b, hkv, sk // block_k, block_k, d)
    vb = v.reshape(b, hkv, sk // block_k, block_k, d)
    o, _ = _flash_forward_blocks(
        qb, kb, vb, causal=causal, q_offset=q_offset,
        block_q=block_q, block_k=block_k,
    )
    return o.reshape(b, hq, sq, d).astype(q.dtype)


def decode_attention(
    q: jax.Array,        # (B, Hq, 1, D)
    k_cache: jax.Array,  # (B, Hkv, S, D)
    v_cache: jax.Array,  # (B, Hkv, S, D)
    length: jax.Array,   # () int32 — number of valid cache positions
) -> jax.Array:
    """One-token attention against a bounded, position-masked KV cache."""
    b, hq, _, d = q.shape
    _, hkv, s, _ = k_cache.shape
    g = hq // hkv
    scale = d ** -0.5
    qg = (q.reshape(b, hkv, g, d) * scale).astype(jnp.float32)
    logits = jnp.einsum(
        "bhgd,bhkd->bhgk", qg, k_cache.astype(jnp.float32)
    )
    valid = jnp.arange(s)[None, None, None] < length
    logits = jnp.where(valid, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, hq, 1, d).astype(q.dtype)


def attention_pallas(q, k, v, *, causal: bool = True, q_offset: int = 0,
                     block_q: int = 512, block_k: int = 512) -> jax.Array:
    from repro.kernels import ops

    return ops.flash_attention(
        q, k, v, causal=causal, q_offset=q_offset,
        block_q=min(block_q, q.shape[2]), block_k=min(block_k, k.shape[2]),
    )


ATTN_IMPLS = {
    "blockwise": blockwise_attention,
    "reference": lambda q, k, v, causal=True, q_offset=0, **_: attention_reference(
        q, k, v, causal=causal, q_offset=q_offset
    ),
    "pallas": attention_pallas,
}


# ---------------------------------------------------------------------------
# Attention layer (projections + rope + impl dispatch + cache)
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, cross: bool = False) -> dict:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    dt = cfg.param_dtype
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, cfg.num_heads * hd), dt),
        "wk": dense_init(ks[1], (d, cfg.num_kv_heads * hd), dt),
        "wv": dense_init(ks[2], (d, cfg.num_kv_heads * hd), dt),
        "wo": dense_init(ks[3], (cfg.num_heads * hd, d), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,), dt)
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,), dt)
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,), dt)
    return p


def _split_heads(x, n_heads, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, s, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * hd)


def attention_layer(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,                   # (B, S, D)
    positions: jax.Array,           # (B, S) int32
    *,
    causal: bool = True,
    mrope_positions: jax.Array | None = None,
    kv_override: tuple[jax.Array, jax.Array] | None = None,  # cross-attn
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Returns (output, (k, v)) — k/v in (B, Hkv, S, hd) layout for caching."""
    hd = cfg.resolved_head_dim
    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"]
    q = _split_heads(q, cfg.num_heads, hd)

    if kv_override is None:
        k = x @ p["wk"]
        v = x @ p["wv"]
        if "bk" in p:
            k, v = k + p["bk"], v + p["bv"]
        k = _split_heads(k, cfg.num_kv_heads, hd)
        v = _split_heads(v, cfg.num_kv_heads, hd)
        cos, sin = rope_cos_sin(
            positions, hd, cfg.rope_theta,
            mrope_sections=cfg.mrope_sections,
            mrope_positions=mrope_positions,
        )
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    else:
        # cross-attention: encoder memory, no RoPE (positions are unrelated)
        k, v = kv_override

    if cfg.attn_impl == "blockwise":
        out = blockwise_attention(
            q, k, v, causal=causal, q_offset=0,
            block_q=cfg.attn_block_q, block_k=cfg.attn_block_k,
            streaming_bwd=cfg.attn_streaming_bwd,
        )
    else:
        impl = ATTN_IMPLS[cfg.attn_impl]
        out = impl(
            q, k, v, causal=causal, q_offset=0,
            block_q=cfg.attn_block_q, block_k=cfg.attn_block_k,
        )
    return _merge_heads(out) @ p["wo"], (k, v)


def attention_decode(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,                   # (B, 1, D)
    pos: jax.Array,                 # () int32 — absolute position of the token
    k_cache: jax.Array,             # (B, Hkv, S, hd)
    v_cache: jax.Array,
    *,
    cross: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step; returns (out, new_k_cache, new_v_cache)."""
    hd = cfg.resolved_head_dim
    b = x.shape[0]
    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"]
    q = _split_heads(q, cfg.num_heads, hd)

    if cross:
        # cross-attention: cache is the (fixed) encoder memory — no RoPE
        out = decode_attention(q, k_cache, v_cache, k_cache.shape[2])
        return _merge_heads(out) @ p["wo"], k_cache, v_cache

    pos_arr = jnp.full((b, 1), pos, jnp.int32)
    cos, sin = rope_cos_sin(pos_arr, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)

    k_new = x @ p["wk"]
    v_new = x @ p["wv"]
    if "bk" in p:
        k_new, v_new = k_new + p["bk"], v_new + p["bv"]
    k_new = _split_heads(k_new, cfg.num_kv_heads, hd)
    k_new = apply_rope(k_new, cos, sin)
    v_new = _split_heads(v_new, cfg.num_kv_heads, hd)
    k_cache = lax.dynamic_update_slice(k_cache, k_new, (0, 0, pos, 0))
    v_cache = lax.dynamic_update_slice(v_cache, v_new, (0, 0, pos, 0))
    out = decode_attention(q, k_cache, v_cache, pos + 1)
    return _merge_heads(out) @ p["wo"], k_cache, v_cache


# ---------------------------------------------------------------------------
# MLP (dense and streamed)
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig) -> dict:
    d, f, dt = cfg.d_model, cfg.d_ff, cfg.param_dtype
    ks = jax.random.split(key, 3)
    p = {
        "wu": dense_init(ks[0], (d, f), dt),
        "wd": dense_init(ks[1], (f, d), dt),
    }
    if cfg.gated_mlp:
        p["wg"] = dense_init(ks[2], (d, f), dt)
    return p


def _act(name: str, x):
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu":
        return jax.nn.relu(x)
    if name == "squared_relu":
        r = jnp.maximum(x, 0.0)
        return r * r
    raise ValueError(name)


def mlp_layer(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.mlp_impl == "streamed":
        return _mlp_streamed(p, cfg, x)
    up = x @ p["wu"]
    if cfg.gated_mlp:
        h = _act(cfg.act, x @ p["wg"]) * up
    else:
        h = _act(cfg.act, up)
    return h @ p["wd"]


def _mlp_streamed(p: dict, cfg: ModelConfig, x: jax.Array,
                  block_f: int = 2048) -> jax.Array:
    """MING streaming applied at graph level: scan over d_ff tiles so the
    (tokens, d_ff) hidden never materializes in HBM."""
    f = cfg.d_ff
    bf = min(block_f, f)
    assert f % bf == 0
    nf = f // bf

    def step(acc, t):
        sl = (0, t * bf)
        wu = lax.dynamic_slice(p["wu"], sl, (x.shape[-1], bf))
        up = x @ wu
        if cfg.gated_mlp:
            wg = lax.dynamic_slice(p["wg"], sl, (x.shape[-1], bf))
            h = _act(cfg.act, x @ wg) * up
        else:
            h = _act(cfg.act, up)
        wd = lax.dynamic_slice(p["wd"], (t * bf, 0), (bf, x.shape[-1]))
        return acc + h @ wd, None

    acc0 = jnp.zeros(x.shape, jnp.float32)
    acc, _ = lax.scan(step, acc0, jnp.arange(nf))
    return acc.astype(x.dtype)
