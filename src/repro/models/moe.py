"""Mixture-of-Experts layer: top-k routing with capacity-bounded
scatter dispatch (GShard-style, no (tokens × E × C) dispatch tensor).

Sharding: the expert axis E shards over ``model`` (expert parallelism);
tokens shard over ``data``.  The scatter/gather crossing the two axes is
where GSPMD inserts the all-to-all — visible in the dry-run collective
schedule (EXPERIMENTS.md §Dry-run).

MING applicability (DESIGN.md §4): the router is a pure-parallel node,
each expert FFN a regular-reduction node; capacity C is the stream-depth
analogue (tokens beyond capacity are dropped, like back-pressured FIFO
writes — standard MoE token dropping, error carried by the residual).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from .layers import _act, dense_init


def init_moe(key, cfg: ModelConfig) -> dict:
    assert cfg.moe is not None
    d, f, dt = cfg.d_model, cfg.d_ff, cfg.param_dtype
    e = cfg.moe.num_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "wu": dense_init(ks[1], (e, d, f), dt, scale=1.0 / math.sqrt(d)),
        "wd": dense_init(ks[2], (e, f, d), dt, scale=1.0 / math.sqrt(f)),
    }
    if cfg.gated_mlp:
        p["wg"] = dense_init(ks[3], (e, d, f), dt, scale=1.0 / math.sqrt(d))
    return p


def expert_capacity(num_tokens: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(math.ceil(num_tokens * m.top_k * m.capacity_factor / m.num_experts))
    return max(8, ((c + 7) // 8) * 8)   # pad to a lane-friendly multiple


def moe_layer(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """x: (B, S, D) → (B, S, D).

    Dispatch/combine are streamed as a ``lax.scan`` over the k routing
    choices: the naive formulation materializes (N·k, D) gather/scatter
    tensors — 17 GiB per layer at train_4k on granite (measured; §Perf
    MoE iteration) — while the per-choice stream peaks at one (N, D).
    This is MING C1 applied to the MoE dispatch: the "intermediate
    tensor" between router and experts is never built.
    """
    m = cfg.moe
    b, s, d = x.shape
    n = b * s
    e, k = m.num_experts, m.top_k
    cap = expert_capacity(n, cfg)

    xf = x.reshape(n, d)
    logits = (xf.astype(jnp.float32) @ p["router"])          # (N, E)
    gate_w, gate_i = lax.top_k(logits, k)                    # (N, k)
    gate_w = jax.nn.softmax(gate_w, axis=-1)

    # position of each (token, choice) within its expert's capacity
    # buffer — index bookkeeping only (int32, no D-sized tensors)
    flat_i = gate_i.reshape(-1)                              # (N*k,)
    onehot = jax.nn.one_hot(flat_i, e, dtype=jnp.int32)      # (N*k, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot                # exclusive
    flat_pos = jnp.take_along_axis(pos, flat_i[:, None], axis=1)[:, 0]
    keep = flat_pos < cap                                    # (N*k,)
    pos_k = flat_pos.reshape(n, k)
    keep_k = keep.reshape(n, k)
    safe_pos = jnp.where(keep_k, pos_k, cap - 1)             # (N, k)

    # dispatch: one (N, D) scatter per routing choice
    def dispatch(buf, kk):
        contrib = jnp.where(keep_k[:, kk][:, None], xf, 0)
        return buf.at[gate_i[:, kk], safe_pos[:, kk]].add(
            contrib, mode="drop"
        ), None

    buf0 = jnp.zeros((e, cap, d), x.dtype)
    buf, _ = lax.scan(dispatch, buf0, jnp.arange(k))

    # expert FFNs (batched over E; E shards over `model`)
    up = jnp.einsum("ecd,edf->ecf", buf, p["wu"])
    if cfg.gated_mlp:
        gate = _act(cfg.act, jnp.einsum("ecd,edf->ecf", buf, p["wg"]))
        h = gate * up
    else:
        h = _act(cfg.act, up)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wd"])         # (E, C, D)

    # combine: one (N, D) gather per choice, f32 accumulator
    def combine(acc, kk):
        picked = out_buf[gate_i[:, kk], safe_pos[:, kk]]     # (N, D)
        w = jnp.where(keep_k[:, kk], gate_w[:, kk], 0.0)
        return acc + picked.astype(jnp.float32) * w[:, None], None

    y0 = jnp.zeros((n, d), jnp.float32)
    y, _ = lax.scan(combine, y0, jnp.arange(k))
    return y.reshape(b, s, d).astype(x.dtype)
