"""Model zoo: decoder-only LM families + encoder-decoder backbone."""
