#!/usr/bin/env python
"""Fail-soft perf-trajectory diff for BENCH_smoke.json / BENCH_serve.json.

Compares the current snapshot against the archived previous one, prints
per-graph (per-target) deltas, then refreshes the archive.

Fail-soft contract (scripts/ci.sh):
  * no archive yet, unreadable archive, schema drift → report + archive,
    exit 0 (the trajectory starts/restarts here);
  * any metric moved → printed delta, exit 0;
  * the hard metric regressed by more than --threshold (default 10%) on
    any row → exit 1 (the only hard failure).

``--mode smoke`` (default) diffs compile snapshots: the hard metric is
``total_cycles``.  ``--mode serve`` (ISSUE 7) diffs serving load rows
(``{model: {target: {"loads": [...]}}}``, keyed by offered QPS): a
>threshold ``p99_ms`` increase *or* ``achieved_qps`` drop hard-fails;
the ``_speedup`` section is informational and never gates.
``--warn-only`` downgrades the hard gate to a report — scripts/ci.sh
uses it for serve rows, because wall-clock numbers on shared CI
runners are noisy-neighbor flaky (the bit-exactness checks elsewhere
in CI stay hard).

The smoke schema is ``{graph: {target: row}}`` since ISSUE 3; the flat
PR 2 ``{graph: row}`` form is still accepted (treated as one "kv260"
target) so the first diff across the schema change stays soft.  Since
ISSUE 6 every row carries a ``provenance`` stamp (git sha, host, wall
times); those keys are measurement jitter, not metrics, and are
stripped before diffing.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys

HARD_METRIC = "total_cycles"
SOFT_METRICS = ("total_cycles", "max_group_cycles", "max_bram", "groups",
                "spill_bytes")
#: per-row measurement stamps (ISSUE 6: git sha, host, wall times) and
#: live metrics snapshots (ISSUE 10: latency histograms, queue-depth
#: series) — jitter by construction, stripped before any comparison so
#: they can never trip the regression gate
IGNORED_KEYS = ("provenance", "metrics")


def _load(path: str) -> dict | None:
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError) as e:
        print(f"# smoke-diff: cannot read {path}: {e}")
        return None
    if not isinstance(data, dict):
        print(f"# smoke-diff: {path} is not a snapshot dict")
        return None
    return data


def _strip_ignored(row: dict) -> dict:
    return {k: v for k, v in row.items() if k not in IGNORED_KEYS}


def _per_target(data: dict) -> dict[tuple[str, str], dict]:
    """Normalize either schema to {(graph, target): row}, dropping
    :data:`IGNORED_KEYS` (provenance stamps) from every row."""
    rows: dict[tuple[str, str], dict] = {}
    for graph, entry in data.items():
        if not isinstance(entry, dict):
            continue
        if any(isinstance(v, dict) and "total_cycles" in v
               for v in entry.values()):
            for target, row in entry.items():
                if isinstance(row, dict):
                    rows[(graph, target)] = _strip_ignored(row)
        elif "total_cycles" in entry:  # PR 2 flat schema
            rows[(graph, "kv260")] = _strip_ignored(entry)
    return rows


def diff(prev: dict, cur: dict, threshold: float, emit=print) -> int:
    """Print deltas; return the number of hard cycle regressions."""
    p, c = _per_target(prev), _per_target(cur)
    regressions = 0
    emit("graph,target,metric,previous,current,delta_pct")
    for key in sorted(c):
        graph, target = key
        if key not in p:
            emit(f"{graph},{target},<new row>,,,")
            continue
        for m in SOFT_METRICS:
            a, b = p[key].get(m), c[key].get(m)
            if not isinstance(a, (int, float)) or not isinstance(b, (int, float)):
                continue
            if a == b:
                continue
            pct = (b - a) / a * 100 if a else float("inf")
            emit(f"{graph},{target},{m},{a},{b},{pct:+.1f}%")
            if m == HARD_METRIC and a and (b - a) / a > threshold:
                emit(f"# REGRESSION: {graph}@{target} {m} "
                     f"{a} -> {b} (+{(b - a) / a * 100:.1f}% > "
                     f"{threshold * 100:.0f}%)")
                regressions += 1
    for key in sorted(set(p) - set(c)):
        emit(f"{key[0]},{key[1]},<row dropped>,,,")
    return regressions


#: serve-row metrics (ISSUE 7): p99 regresses *up*, throughput *down*;
#: the rest print fail-soft
SERVE_SOFT_METRICS = ("achieved_qps", "p50_ms", "p99_ms", "mean_ms",
                      "mean_batch", "rejected")


def _per_load(data: dict) -> dict[tuple[str, str, float], dict]:
    """Normalize a serve snapshot to {(model, target, offered_qps):
    row}; ``_``-prefixed sections (the speedup gate) and provenance
    stamps are not trajectory rows."""
    rows: dict[tuple[str, str, float], dict] = {}
    for model, entry in data.items():
        if model.startswith("_") or not isinstance(entry, dict):
            continue
        for target, cell in entry.items():
            if not isinstance(cell, dict):
                continue
            for row in cell.get("loads", ()):
                if isinstance(row, dict) and "offered_qps" in row:
                    rows[(model, target, row["offered_qps"])] = \
                        _strip_ignored(row)
    return rows


def diff_serve(prev: dict, cur: dict, threshold: float, emit=print) -> int:
    """Print serve-row deltas; return the hard regression count."""
    p, c = _per_load(prev), _per_load(cur)
    regressions = 0
    emit("model,target,offered_qps,metric,previous,current,delta_pct")
    for key in sorted(c):
        model, target, q = key
        if key not in p:
            emit(f"{model},{target},{q},<new row>,,,")
            continue
        for m in SERVE_SOFT_METRICS:
            a, b = p[key].get(m), c[key].get(m)
            if not isinstance(a, (int, float)) \
                    or not isinstance(b, (int, float)):
                continue
            if a == b:
                continue
            pct = (b - a) / a * 100 if a else float("inf")
            emit(f"{model},{target},{q},{m},{a},{b},{pct:+.1f}%")
            worse = (
                (m == "p99_ms" and a and (b - a) / a > threshold)
                or (m == "achieved_qps" and a and (a - b) / a > threshold)
            )
            if worse:
                emit(f"# REGRESSION: {model}@{target} qps={q} {m} "
                     f"{a} -> {b} (> {threshold * 100:.0f}%)")
                regressions += 1
    for key in sorted(set(p) - set(c)):
        emit(f"{key[0]},{key[1]},{key[2]},<row dropped>,,,")
    return regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", nargs="?", default=None)
    ap.add_argument("--mode", choices=("smoke", "serve"), default="smoke",
                    help="snapshot schema: compile rows or serve load rows")
    ap.add_argument("--archive", default=None,
                    help="previous snapshot (refreshed on every run)")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="hard-fail fraction for the mode's hard metrics")
    ap.add_argument("--warn-only", action="store_true",
                    help="report regressions but always exit 0; CI uses "
                         "this for the timing-sensitive serve rows "
                         "(wall-clock on shared runners is noisy), "
                         "keeping the diff informational. The archive "
                         "still refreshes.")
    args = ap.parse_args(argv)
    if args.current is None:
        args.current = ("BENCH_smoke.json" if args.mode == "smoke"
                        else "BENCH_serve.json")
    if args.archive is None:
        args.archive = (".bench/BENCH_smoke.prev.json"
                        if args.mode == "smoke"
                        else ".bench/BENCH_serve.prev.json")

    cur = _load(args.current)
    if cur is None:
        print("# smoke-diff: no current snapshot — nothing to do")
        return 0

    rc = 0
    prev = _load(args.archive) if os.path.exists(args.archive) else None
    if prev is None:
        print(f"# smoke-diff: no previous snapshot at {args.archive} — "
              "archiving this run as the new baseline")
    else:
        differ = diff if args.mode == "smoke" else diff_serve
        n = differ(prev, cur, args.threshold)
        if n:
            print(f"# smoke-diff: {n} hard regression(s) "
                  f"(> {args.threshold * 100:.0f}%)")
            if args.warn_only:
                print("# smoke-diff: --warn-only — reported, not failing")
            else:
                rc = 1
        else:
            print("# smoke-diff: no hard regressions")

    if rc == 0:
        # keep the pre-regression baseline on failure so a re-run does
        # not silently accept the regression as the new normal (delete
        # the archive, or raise --threshold, to accept intentionally)
        os.makedirs(os.path.dirname(args.archive) or ".", exist_ok=True)
        shutil.copyfile(args.current, args.archive)
    else:
        print(f"# smoke-diff: baseline at {args.archive} left unchanged")
    return rc


if __name__ == "__main__":
    sys.exit(main())
