#!/usr/bin/env python
"""Fail-soft perf-trajectory diff for BENCH_smoke.json.

Compares the current snapshot against the archived previous one, prints
per-graph (per-target) cycle/BRAM deltas, then refreshes the archive.

Fail-soft contract (scripts/ci.sh):
  * no archive yet, unreadable archive, schema drift → report + archive,
    exit 0 (the trajectory starts/restarts here);
  * any metric moved → printed delta, exit 0;
  * total_cycles regressed by more than --threshold (default 10%) on
    any graph → exit 1 (the only hard failure).

The snapshot schema is ``{graph: {target: row}}`` since ISSUE 3; the
flat PR 2 ``{graph: row}`` form is still accepted (treated as one
"kv260" target) so the first diff across the schema change stays soft.
Since ISSUE 6 every row carries a ``provenance`` stamp (git sha, host,
wall times); those keys are measurement jitter, not metrics, and are
stripped before diffing.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys

HARD_METRIC = "total_cycles"
SOFT_METRICS = ("total_cycles", "max_group_cycles", "max_bram", "groups",
                "spill_bytes")
#: per-row measurement stamps (ISSUE 6: git sha, host, wall times) —
#: jitter by construction, stripped before any comparison so they can
#: never trip the regression gate
IGNORED_KEYS = ("provenance",)


def _load(path: str) -> dict | None:
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError) as e:
        print(f"# smoke-diff: cannot read {path}: {e}")
        return None
    if not isinstance(data, dict):
        print(f"# smoke-diff: {path} is not a snapshot dict")
        return None
    return data


def _strip_ignored(row: dict) -> dict:
    return {k: v for k, v in row.items() if k not in IGNORED_KEYS}


def _per_target(data: dict) -> dict[tuple[str, str], dict]:
    """Normalize either schema to {(graph, target): row}, dropping
    :data:`IGNORED_KEYS` (provenance stamps) from every row."""
    rows: dict[tuple[str, str], dict] = {}
    for graph, entry in data.items():
        if not isinstance(entry, dict):
            continue
        if any(isinstance(v, dict) and "total_cycles" in v
               for v in entry.values()):
            for target, row in entry.items():
                if isinstance(row, dict):
                    rows[(graph, target)] = _strip_ignored(row)
        elif "total_cycles" in entry:  # PR 2 flat schema
            rows[(graph, "kv260")] = _strip_ignored(entry)
    return rows


def diff(prev: dict, cur: dict, threshold: float, emit=print) -> int:
    """Print deltas; return the number of hard cycle regressions."""
    p, c = _per_target(prev), _per_target(cur)
    regressions = 0
    emit("graph,target,metric,previous,current,delta_pct")
    for key in sorted(c):
        graph, target = key
        if key not in p:
            emit(f"{graph},{target},<new row>,,,")
            continue
        for m in SOFT_METRICS:
            a, b = p[key].get(m), c[key].get(m)
            if not isinstance(a, (int, float)) or not isinstance(b, (int, float)):
                continue
            if a == b:
                continue
            pct = (b - a) / a * 100 if a else float("inf")
            emit(f"{graph},{target},{m},{a},{b},{pct:+.1f}%")
            if m == HARD_METRIC and a and (b - a) / a > threshold:
                emit(f"# REGRESSION: {graph}@{target} {m} "
                     f"{a} -> {b} (+{(b - a) / a * 100:.1f}% > "
                     f"{threshold * 100:.0f}%)")
                regressions += 1
    for key in sorted(set(p) - set(c)):
        emit(f"{key[0]},{key[1]},<row dropped>,,,")
    return regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", nargs="?", default="BENCH_smoke.json")
    ap.add_argument("--archive", default=".bench/BENCH_smoke.prev.json",
                    help="previous snapshot (refreshed on every run)")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="hard-fail fraction for total_cycles regressions")
    args = ap.parse_args(argv)

    cur = _load(args.current)
    if cur is None:
        print("# smoke-diff: no current snapshot — nothing to do")
        return 0

    rc = 0
    prev = _load(args.archive) if os.path.exists(args.archive) else None
    if prev is None:
        print(f"# smoke-diff: no previous snapshot at {args.archive} — "
              "archiving this run as the new baseline")
    else:
        n = diff(prev, cur, args.threshold)
        if n:
            print(f"# smoke-diff: {n} hard cycle regression(s) "
                  f"(> {args.threshold * 100:.0f}%)")
            rc = 1
        else:
            print("# smoke-diff: no hard regressions")

    if rc == 0:
        # keep the pre-regression baseline on failure so a re-run does
        # not silently accept the regression as the new normal (delete
        # the archive, or raise --threshold, to accept intentionally)
        os.makedirs(os.path.dirname(args.archive) or ".", exist_ok=True)
        shutil.copyfile(args.current, args.archive)
    else:
        print(f"# smoke-diff: baseline at {args.archive} left unchanged")
    return rc


if __name__ == "__main__":
    sys.exit(main())
