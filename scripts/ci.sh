#!/usr/bin/env bash
# Tier-1 CI gate: unit tests + model-only benchmark smoke.
# Usage: scripts/ci.sh  (from anywhere; cds to the repo root itself)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

python -m pytest -q
python -m benchmarks.run --smoke
