#!/usr/bin/env bash
# Tier-1 CI gate: unit tests + model-only benchmark smoke.
# Usage: scripts/ci.sh [--full]   (from anywhere; cds to the repo root)
#   --full  additionally runs the kernel interpret-mode validation:
#           benchmarks/run.py without --smoke executes every Pallas
#           kernel against its ref.py oracle on CPU — slower, so gated
#           behind the flag (ROADMAP "once runtime is budgeted" item).
set -euo pipefail
cd "$(dirname "$0")/.."

FULL=0
for arg in "$@"; do
  case "$arg" in
    --full) FULL=1 ;;
    *) echo "unknown flag: $arg" >&2; exit 2 ;;
  esac
done

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

python -m pytest -q

# public-API smoke: the CLI front door must compile + emit end to end
# (exercises repro.api: builder suite -> CompileOptions -> artifact)
CLI_OUT="$(mktemp -d)"
python -m repro list > /dev/null
python -m repro compile conv_relu_32 --target kv260 --emit "$CLI_OUT" --quiet
test -s "$CLI_OUT/conv_relu_32_g0.cpp"
test -s "$CLI_OUT/host_schedule.cpp"
rm -rf "$CLI_OUT"

# importer smoke (ISSUE 5): a zoo model card must compile -> emit -> run
# end to end through `python -m repro compile <file>` (repro.frontends)
ZOO_OUT="$(mktemp -d)"
python -m repro zoo > /dev/null
RUN_LOG="$(python -m repro compile examples/lenet5.json --target kv260 \
  --emit "$ZOO_OUT" --run --quiet)"
echo "$RUN_LOG" | grep -q "ran OK"
test -s "$ZOO_OUT/lenet5_g0.cpp"
test -s "$ZOO_OUT/host_schedule.cpp"
rm -rf "$ZOO_OUT"

# strided-ONNX smoke (ISSUE 8): the strided+BN golden fixture must
# import -> compile -> emit -> run end to end through the CLI (stride-2
# downsamples, BatchNorm folds, GlobalAveragePool head), traced; the
# trace is kept as trace_onnx_smoke.json for the artifact upload like
# the lenet5 one below
ONNX_OUT="$(mktemp -d)"
RUN_LOG="$(python -m repro compile tests/golden/resnet_tiny.onnx \
  --target kv260 --emit "$ONNX_OUT" --run --quiet \
  --trace /tmp/trace_onnx.json)"
echo "$RUN_LOG" | grep -q "ran OK"
test -s "$ONNX_OUT/resnet_tiny_g0.cpp"
test -s "$ONNX_OUT/host_schedule.cpp"
rm -rf "$ONNX_OUT"
python - /tmp/trace_onnx.json <<'PY'
import json, sys
from repro.instrument import validate_chrome_trace
validate_chrome_trace(json.load(open(sys.argv[1])))
print("onnx trace OK")
PY
cp /tmp/trace_onnx.json trace_onnx_smoke.json

# instrumentation smoke (ISSUE 6): a traced compile+run must produce a
# valid Chrome trace-event JSON; kept as trace_smoke.json for the
# workflow artifact upload alongside the provenance-stamped BENCH rows
python -m repro compile lenet5 --trace /tmp/trace.json --run --quiet > /dev/null
python - /tmp/trace.json <<'PY'
import json, sys
from repro.instrument import validate_chrome_trace
obj = validate_chrome_trace(json.load(open(sys.argv[1])))
names = [e["name"] for e in obj["traceEvents"]]
assert any(n.startswith("pass:") for n in names), "no pass spans in trace"
assert any(n.startswith("run:") for n in names), "no runtime spans in trace"
assert "provenance" in obj.get("otherData", {}), "trace missing provenance"
print(f"trace OK ({len(names)} events)")
PY
cp /tmp/trace.json trace_smoke.json

# lint gate (ISSUE 9): the static analyzer must find zero ERROR-severity
# diagnostics across the whole named suite (paper suite + showcases +
# zoo) on both device presets.  The full JSON diagnostics document is
# kept as lint_diagnostics.json for the workflow artifact upload.
python -m repro lint --all --target kv260 --target zu3eg \
  --json lint_diagnostics.json --quiet
python - lint_diagnostics.json <<'PY'
import json, sys
doc = json.load(open(sys.argv[1]))
assert doc["version"] == 1 and doc["counts"]["error"] == 0, doc["counts"]
print(f"lint OK ({sum(doc['counts'].values())} diagnostics, 0 errors "
      f"across {len(doc['meta']['graphs'])} graph/target pairs)")
PY

if [ "$FULL" = 1 ]; then
  python -m benchmarks.run          # includes kernel interpret-mode checks
else
  python -m benchmarks.run --smoke  # model-only sections + BENCH_smoke.json
fi

# perf-trajectory gate: diff BENCH_smoke.json against the archived
# previous snapshot (fail-soft: only a >10% cycle regression hard-fails;
# a missing archive just seeds the trajectory), then refresh the archive.
python scripts/smoke_diff.py BENCH_smoke.json

# profiler smoke (ISSUE 10): the modeled-vs-measured join must produce
# a per-group table and a schema-valid JSON document; kept as
# profile_smoke.json for the workflow artifact upload.  Wall-clock
# ratios on shared runners are noise — the gate is structural (groups
# present, modeled cycles joined, ratio computed), never a threshold.
python -m repro profile lenet5 --reps 1 --json profile_smoke.json --quiet
python - profile_smoke.json <<'PY'
import json, sys
doc = json.load(open(sys.argv[1]))
assert doc["version"] == 1 and doc["profiles"], "empty profile document"
for prof in doc["profiles"]:
    assert prof["groups"], f"{prof['model']}: no group rows"
    for g in prof["groups"]:
        assert g["modeled_cycles"] > 0 and g["measured_ms"] > 0, g
        assert "ratio" in g and "implied_clock_mhz" in g, g
    assert prof["layers"], f"{prof['model']}: no layer rows"
print(f"profile OK ({len(doc['profiles'])} target(s), "
      f"{sum(len(p['groups']) for p in doc['profiles'])} group rows)")
PY

# serving smoke (ISSUE 7): a short fixed-seed load test on lenet5
# produces BENCH_serve.json for the workflow artifact.  Bit-exactness
# (vmap vs loop) is the hard gate; the wall-clock numbers — the 5x
# speedup and the p99/QPS trajectory diff — are *informational* here
# (--min-speedup 0, --warn-only) because timing on shared CI runners
# is noisy-neighbor flaky.  Dev invocations without those flags keep
# the full-threshold gates.  The engine's metrics snapshot (ISSUE 10)
# rides along as serve_metrics.json and must validate + carry the
# lifecycle series the load test exercised.
python -m benchmarks.serve_bench --models lenet5 --targets kv260 \
  --qps 100,400 --requests 120 --seed 0 --min-speedup 0 \
  --metrics-out serve_metrics.json
python - serve_metrics.json <<'PY'
import json, sys
from repro.instrument import validate_metrics_snapshot
snap = validate_metrics_snapshot(json.load(open(sys.argv[1])))
assert snap["counters"]["serve_requests_total"]["values"], "no requests"
stages = {row["labels"]["stage"]
          for row in snap["histograms"]["serve_stage_ms"]["values"]}
assert stages >= {"queue_wait", "batch_form", "execute", "respond"}, stages
print(f"serve metrics OK (stages: {sorted(stages)})")
PY
python scripts/smoke_diff.py BENCH_serve.json --mode serve --warn-only
